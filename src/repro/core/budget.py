"""Tier-decomposed SLA budgets — shared by east-west federation and
split (device–RAN–cloud) placement.

One ASP carries END-TO-END objectives; any placement that spans more than
one leg (a visited operator behind a transit link, or a split session
whose draft and verify anchors sit at different tiers) must hand each leg
an explicit share of those objectives, never the raw bounds::

    ℓ_leg = ℓ − t_leg           for ℓ ∈ {ℓ_TTFB, ℓ_0.95, ℓ_0.99, T_max}
    γ_leg = γ · s_leg           with Σ s_leg ≤ 1

A decomposition with any non-positive execution share is *infeasible
before any traffic is generated* and maps to ``NO_FEASIBLE_BINDING``
(Eq. 12) — a leg is never asked to promise what its transport already
consumed. ``decompose_budget`` is the two-party (home/visited) form the
federation wire speaks; ``decompose_tiers`` generalizes it to N named
tiers for split placement (edge draft + regional/central verify).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Dict, Mapping, Optional

from repro.core.asp import ASP
from repro.core.failures import FailureCause, SessionError


@dataclass(frozen=True)
class SLABudget:
    """Per-leg split of one ASP's objectives (all ms except cost)."""
    ttfb_ms: float              # execution share of ℓ_TTFB
    p95_ms: float
    p99_ms: float               # execution share of ℓ_0.99
    t_max_ms: float
    max_cost_per_1k: float      # execution share of γ
    home_transport_ms: float    # the transport share withheld (audit)
    home_cost_per_1k: float     # withheld transit/retail cost share (audit)

    def to_wire(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_wire(cls, d: dict) -> "SLABudget":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: float(v) for k, v in d.items() if k in names})


def decompose_budget(asp: ASP, home_transport_ms: float, *,
                     home_cost_share: float = 0.15) -> SLABudget:
    """Split the ASP objectives between the withheld transport leg and the
    execution leg. Raises ``NO_FEASIBLE_BINDING`` when the transport share
    alone exhausts any bound — the infeasibility is attributable *before*
    any east-west (or split-PREPARE) traffic is generated."""
    o = asp.objectives
    visited = {
        "ttfb_ms": o.ttfb_ms - home_transport_ms,
        "p95_ms": o.p95_ms - home_transport_ms,
        "p99_ms": o.p99_ms - home_transport_ms,
        "t_max_ms": o.t_max_ms - home_transport_ms,
    }
    if min(visited.values()) <= 0.0:
        raise SessionError(
            FailureCause.NO_FEASIBLE_BINDING,
            f"SLA budget infeasible after decomposition: home transport "
            f"share {home_transport_ms:.1f}ms exhausts "
            f"{min(visited, key=visited.get)}")
    if not (0.0 <= home_cost_share < 1.0):
        raise ValueError("home_cost_share must be in [0, 1)")
    home_cost = asp.max_cost_per_1k_tokens * home_cost_share
    return SLABudget(
        ttfb_ms=visited["ttfb_ms"], p95_ms=visited["p95_ms"],
        p99_ms=visited["p99_ms"], t_max_ms=visited["t_max_ms"],
        max_cost_per_1k=asp.max_cost_per_1k_tokens - home_cost,
        home_transport_ms=home_transport_ms, home_cost_per_1k=home_cost)


def decompose_tiers(asp: ASP, transport_ms: Mapping[str, float], *,
                    cost_shares: Optional[Mapping[str, float]] = None
                    ) -> Dict[str, SLABudget]:
    """Tier-generalized decomposition: each named tier keeps its OWN
    transport leg (edge RTT for the draft anchor, backhaul RTT for the
    verify anchor) and receives ``ℓ − t_tier`` of every latency bound plus
    its cost share of γ (equal split unless ``cost_shares`` says
    otherwise). Any tier whose transport exhausts a bound makes the whole
    split infeasible — raised as ``NO_FEASIBLE_BINDING`` naming the tier,
    so DISCOVER can fall back to single-anchor placement attributably."""
    if not transport_ms:
        raise ValueError("decompose_tiers needs at least one tier")
    shares = dict(cost_shares or {})
    unnamed = [t for t in transport_ms if t not in shares]
    remaining = 1.0 - sum(shares.values())
    if remaining < -1e-9 or any(s < 0.0 for s in shares.values()):
        raise ValueError("tier cost shares must be >= 0 and sum to <= 1")
    for t in unnamed:
        shares[t] = remaining / len(unnamed) if unnamed else 0.0
    out: Dict[str, SLABudget] = {}
    for tier, t_ms in transport_ms.items():
        try:
            out[tier] = decompose_budget(
                asp, float(t_ms),
                home_cost_share=min(max(1.0 - shares[tier], 0.0),
                                    1.0 - 1e-9))
        except SessionError as e:
            raise SessionError(
                FailureCause.NO_FEASIBLE_BINDING,
                f"tier {tier!r}: {e.detail}") from None
    return out


def apply_budget(asp: ASP, budget: SLABudget) -> ASP:
    """The executing leg's view of the contract: the same constraint part
    (modality, sovereignty, mobility, ladder) under its execution share of
    the objectives and cost envelope."""
    return replace(
        asp,
        objectives=replace(asp.objectives, ttfb_ms=budget.ttfb_ms,
                           p95_ms=budget.p95_ms, p99_ms=budget.p99_ms,
                           t_max_ms=budget.t_max_ms),
        max_cost_per_1k_tokens=budget.max_cost_per_1k)
