"""Boundary telemetry Z(t) (Eq. 13) + compliance evaluation (Eq. 5/16).

Maintains a sliding window of per-request boundary observations and exposes

    Z(t) = (T̂ff, Q̂_L(0.95), Q̂_L(0.99), ρ̂, q̂, ν̂)

Everything is measured at the invoker–service boundary; nothing depends on
internal state — this is what keeps the ASP falsifiable (Section III-C).
Quantiles use exact order statistics over the window (windows are ≤ O(10⁴)
requests; P² isn't needed and exactness simplifies the property tests).
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.asp import ASP


@dataclass
class RequestRecord:
    t_submit: float
    ttfb_ms: float
    latency_ms: float
    completed: bool           # finished within T_max
    tokens: int = 0
    queue_ms: float = 0.0


@dataclass
class ZSnapshot:
    """Eq. (13)."""
    t_ff_ms: float
    q95_ms: float
    q99_ms: float
    rho: float                # completion probability under T_max
    queue_proxy_ms: float
    nu_tokens_per_s: float
    n: int


@dataclass
class ComplianceReport:
    in_compliance: bool
    ttfb_ok: bool
    p95_ok: bool
    p99_ok: bool
    rho_ok: bool
    nu_ok: bool
    z: ZSnapshot


class BoundaryTelemetry:
    def __init__(self, window: int = 2048):
        self.window = window
        self._records: List[RequestRecord] = []

    def record(self, rec: RequestRecord) -> None:
        self._records.append(rec)
        if len(self._records) > self.window:
            self._records = self._records[-self.window:]

    def __len__(self):
        return len(self._records)

    # ------------------------------------------------------------------
    def snapshot(self) -> Optional[ZSnapshot]:
        if not self._records:
            return None
        rs = self._records
        lat = np.array([r.latency_ms for r in rs if r.completed])
        ttfb = np.array([r.ttfb_ms for r in rs if r.completed])
        if lat.size == 0:
            lat = np.array([float("inf")])
            ttfb = np.array([float("inf")])
        tok = sum(r.tokens for r in rs)
        dur_s = max(sum(r.latency_ms for r in rs) / 1e3, 1e-9)
        return ZSnapshot(
            t_ff_ms=float(np.median(ttfb)),
            q95_ms=float(np.quantile(lat, 0.95)),
            q99_ms=float(np.quantile(lat, 0.99)),
            rho=float(np.mean([r.completed for r in rs])),
            queue_proxy_ms=float(np.mean([r.queue_ms for r in rs])),
            nu_tokens_per_s=tok / dur_s,
            n=len(rs))

    def compliance(self, asp: ASP) -> Optional[ComplianceReport]:
        """Eq. (5)/(16): evaluate Z(t) against the ASP bounds."""
        z = self.snapshot()
        if z is None:
            return None
        o = asp.objectives
        ttfb_ok = z.t_ff_ms <= o.ttfb_ms
        p95_ok = z.q95_ms <= o.p95_ms
        p99_ok = z.q99_ms <= o.p99_ms
        rho_ok = z.rho >= o.rho_min
        nu_ok = z.nu_tokens_per_s >= o.nu_min or z.nu_tokens_per_s == 0.0
        return ComplianceReport(
            in_compliance=ttfb_ok and p95_ok and p99_ok and rho_ok and nu_ok,
            ttfb_ok=ttfb_ok, p95_ok=p95_ok, p99_ok=p99_ok, rho_ok=rho_ok,
            nu_ok=nu_ok, z=z)

    def violation_rate(self, asp: ASP) -> float:
        """Per-request ASP violation frequency (Eq. 16 semantics): a served
        request is non-compliant iff L > ℓ99 or L > T_max."""
        if not self._records:
            return 0.0
        o = asp.objectives
        bad = sum(1 for r in self._records
                  if (not r.completed) or r.latency_ms > o.p99_ms
                  or r.latency_ms > o.t_max_ms)
        return bad / len(self._records)
