"""DISCOVER (Eq. 7/8): ASP → ranked admissible (model, site) candidates.

Membership in 𝒦 is determined by *hard* constraints (sovereignty, privacy
scope, quality tier, hardware residency); ranking by the compliance-margin
slack score

    Δ(m,e) = min(ℓ99 − L̂99(m,e), ℓ_ff − T̂ff(m,e)) − λ·Γ̂(m,e)      (Eq. 8)

Candidates with Δ < 0 are predicted to violate at least one bound after cost
policy and are excluded from the admissible set (they remain visible in the
annotated output for diagnosability — "no feasible binding" must be
attributable, Eq. 12).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.core.asp import ASP
from repro.core.catalog import Catalog, ModelEntry
from repro.core.failures import FailureCause, SessionError
from repro.core.predictors import Prediction, Predictors
from repro.core.qos import TransportClass, PREMIUM, BEST_EFFORT


@dataclass
class Candidate:
    model: ModelEntry
    site_id: str
    prediction: Prediction
    slack: float                 # Δ(m, e)
    klass: TransportClass
    admissible: bool
    exclusion_reason: str = ""


def discover(asp: ASP, catalog: Catalog, sites, predictors: Predictors,
             zone: str, *, lam: float = 0.05, prompt_tokens: int = 512,
             gen_tokens: int = 256, analytics=None) -> List[Candidate]:
    """Materialise the annotated candidate set 𝒦 (Eq. 7)."""
    asp.validate()
    models = catalog.admissible(asp)
    if not models:
        raise SessionError(FailureCause.MODEL_UNAVAILABLE,
                           f"no catalog entry admits modality="
                           f"{asp.modality.value} tier≥{int(asp.tier)}")
    klass = PREMIUM if asp.tier >= 2 else BEST_EFFORT
    out: List[Candidate] = []
    for model in models:
        key = f"{model.model_id}@{model.version}"
        for site_id, site in sites.items():
            # ---- hard constraints (membership in 𝒦) -----------------
            if site.spec.region not in asp.allowed_regions:
                out.append(Candidate(model, site_id, None, float("-inf"),
                                     klass, False, "sovereignty"))
                continue
            if set(model.regions).isdisjoint({site.spec.region}):
                out.append(Candidate(model, site_id, None, float("-inf"),
                                     klass, False, "model-region-license"))
                continue
            if not site.hosts(key):
                out.append(Candidate(model, site_id, None, float("-inf"),
                                     klass, False, "not-resident"))
                continue
            if analytics is not None and \
                    not analytics.site_context(site_id).healthy:
                out.append(Candidate(model, site_id, None, float("-inf"),
                                     klass, False, "a1-denied"))
                continue
            # ---- annotate with predicted boundary quantities ----------
            pred = predictors.predict(asp, model, site, zone, klass,
                                      prompt_tokens=prompt_tokens,
                                      gen_tokens=gen_tokens)
            slack = min(asp.objectives.p99_ms - pred.l99_ms,
                        asp.objectives.ttfb_ms - pred.t_ff_ms) \
                - lam * pred.cost_per_1k
            admissible = slack >= 0 and \
                pred.cost_per_1k <= asp.max_cost_per_1k_tokens
            reason = "" if admissible else (
                "cost-envelope" if pred.cost_per_1k > asp.max_cost_per_1k_tokens
                else "negative-slack")
            out.append(Candidate(model, site_id, pred, slack, klass,
                                 admissible, reason))
    out.sort(key=lambda c: c.slack, reverse=True)
    return out


def admissible_set(candidates: List[Candidate]) -> List[Candidate]:
    k = [c for c in candidates if c.admissible]
    if not k:
        reasons = {c.exclusion_reason for c in candidates}
        raise SessionError(
            FailureCause.NO_FEASIBLE_BINDING,
            f"all candidates excluded ({', '.join(sorted(reasons))})")
    return k
