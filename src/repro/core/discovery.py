"""DISCOVER (Eq. 7/8): ASP → ranked admissible (model, site) candidates.

Membership in 𝒦 is determined by *hard* constraints (sovereignty, privacy
scope, quality tier, hardware residency); ranking by the compliance-margin
slack score

    Δ(m,e) = min(ℓ99 − L̂99(m,e), ℓ_ff − T̂ff(m,e)) − λ·Γ̂(m,e)      (Eq. 8)

Candidates with Δ < 0 are predicted to violate at least one bound after cost
policy and are excluded from the admissible set (they remain visible in the
annotated output for diagnosability — "no feasible binding" must be
attributable, Eq. 12).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List

from repro.core.asp import ASP
from repro.core.catalog import Catalog, ModelEntry
from repro.core.failures import FailureCause, SessionError
from repro.core.predictors import Prediction, Predictors
from repro.core.qos import TransportClass, PREMIUM, BEST_EFFORT


@dataclass
class Candidate:
    model: ModelEntry
    site_id: str
    prediction: Prediction
    slack: float                 # Δ(m, e)
    klass: TransportClass
    admissible: bool
    exclusion_reason: str = ""
    #: owning administrative domain of an east-west offer; "" = local.
    #: In a merged federated set, exclusion reasons are prefixed with the
    #: owning domain so NO_FEASIBLE_BINDING stays attributable (Eq. 12).
    domain: str = ""
    region: str = ""             # site region (sovereignty check w/o sites)

    def to_wire(self, *, include_prediction: bool = False) -> dict:
        """Annotated-candidate wire entry — the ONE shape both the
        northbound ``DiscoverResponse`` and the east-west
        ``DiscoverOffer`` carry (offers add the predicted boundary
        quantities; the northbound surface exposes only the slack)."""
        out = {
            "model_id": self.model.model_id,
            "model_version": self.model.version,
            "site_id": self.site_id, "klass": self.klass.name,
            "admissible": self.admissible,
            "slack": self.slack if self.prediction is not None else None,
            "exclusion_reason": self.exclusion_reason,
            "domain": self.domain, "region": self.region,
        }
        if include_prediction:
            out["prediction"] = dataclasses.asdict(self.prediction) \
                if self.prediction is not None else None
        return out


def discover(asp: ASP, catalog: Catalog, sites, predictors: Predictors,
             zone: str, *, lam: float = 0.05, prompt_tokens: int = 512,
             gen_tokens: int = 256, analytics=None,
             models=None, breakers=None) -> List[Candidate]:
    """Materialise the annotated candidate set 𝒦 (Eq. 7).

    ``models`` overrides the catalog's ASP-admissible entries with an
    explicit candidate list — the split-placement path scores DRAFT
    models this way, because a draft runs below the ASP's quality tier
    by construction (the verifier carries the tier; the draft only has
    to be latency/cost-feasible on its leg's budget share)."""
    asp.validate()
    if models is None:
        models = catalog.admissible(asp)
    if not models:
        raise SessionError(FailureCause.MODEL_UNAVAILABLE,
                           f"no catalog entry admits modality="
                           f"{asp.modality.value} tier≥{int(asp.tier)}")
    # tenant adapter binding: resolve once; unknown ids exclude every
    # candidate (PREPARE re-checks and raises NO_FEASIBLE_BINDING)
    adapter = None
    adapter_known = True
    if asp.adapter_id:
        adapters = getattr(catalog, "adapters", None)
        try:
            adapter = adapters.get(asp.adapter_id) if adapters else None
        except KeyError:
            adapter = None
        adapter_known = adapter is not None
    ladder_models = {m for m, _ in asp.fallback_ladder}
    klass = PREMIUM if asp.tier >= 2 else BEST_EFFORT
    # breaker verdicts are memoised per discover() call: allow() mutates
    # the open → half-open probe state, and one DISCOVER must not burn
    # several probe admissions (or give the same site both answers)
    breaker_ok: dict = {}
    out: List[Candidate] = []
    for model in models:
        key = f"{model.model_id}@{model.version}"
        for site_id, site in sites.items():
            # guest views of other domains' sites are reached through the
            # east-west DISCOVER solicitation, never as local candidates
            if getattr(site, "is_guest_view", False):
                continue
            region = site.spec.region

            def _excl(reason: str) -> Candidate:
                return Candidate(model, site_id, None, float("-inf"),
                                 klass, False, reason, region=region)

            # ---- hard constraints (membership in 𝒦) -----------------
            if region not in asp.allowed_regions:
                out.append(_excl("sovereignty"))
                continue
            if set(model.regions).isdisjoint({region}):
                out.append(_excl("model-region-license"))
                continue
            if not site.hosts(key):
                out.append(_excl("not-resident"))
                continue
            # ---- tenant adapter admissibility ------------------------
            if asp.adapter_id:
                if not adapter_known:
                    out.append(_excl("adapter-unknown"))
                    continue
                if model.model_id == adapter.base_model_id:
                    # "base+adapter at the edge": the adapter's own
                    # sovereignty tags gate the site, on top of the
                    # base model's license
                    if model.version != adapter.base_model_version:
                        out.append(_excl("adapter-base-mismatch"))
                        continue
                    if region not in adapter.regions:
                        out.append(_excl("adapter-region"))
                        continue
                elif model.model_id not in ladder_models:
                    # a non-base model is only admissible as a declared
                    # "full model in region" rung of the fallback ladder
                    out.append(_excl("adapter-base-mismatch"))
                    continue
            if site.slots_in_use() >= site.spec.decode_slots:
                # current occupancy IS a feasibility signal: a saturated
                # site would only fail later at PREPARE with
                # COMPUTE_SCARCITY — surfacing it here lets home-first
                # federation spill the establish instead
                out.append(_excl("compute-saturated"))
                continue
            if analytics is not None:
                ctx = analytics.site_context(site_id)
                if not ctx.alive:
                    # supervisor crash verdict: distinct from policy denial
                    # so the Eq. 12 detail string names the real cause
                    out.append(_excl("site-dead"))
                    continue
                if not ctx.healthy:
                    out.append(_excl("a1-denied"))
                    continue
            if breakers is not None:
                ok = breaker_ok.get(site_id)
                if ok is None:
                    ok = breaker_ok[site_id] = breakers.allow(site_id)
                if not ok:
                    # circuit open after consecutive control-plane failures:
                    # the site may be fine — we are backing off the *path*
                    # until the half-open probe readmits it
                    out.append(_excl("circuit-open"))
                    continue
            # ---- annotate with predicted boundary quantities ----------
            pred = predictors.predict(asp, model, site, zone, klass,
                                      prompt_tokens=prompt_tokens,
                                      gen_tokens=gen_tokens)
            slack = min(asp.objectives.p99_ms - pred.l99_ms,
                        asp.objectives.ttfb_ms - pred.t_ff_ms) \
                - lam * pred.cost_per_1k
            admissible = slack >= 0 and \
                pred.cost_per_1k <= asp.max_cost_per_1k_tokens
            reason = "" if admissible else (
                "cost-envelope" if pred.cost_per_1k > asp.max_cost_per_1k_tokens
                else "negative-slack")
            out.append(Candidate(model, site_id, pred, slack, klass,
                                 admissible, reason, region=region))
    out.sort(key=lambda c: c.slack, reverse=True)
    return out


def admissible_set(candidates: List[Candidate]) -> List[Candidate]:
    k = [c for c in candidates if c.admissible]
    if not k:
        reasons = {c.exclusion_reason for c in candidates}
        # strip federation domain prefixes for the cause decision — the
        # full (domain-qualified) reasons stay in the detail string
        bare = {r.split(":", 1)[-1] for r in reasons}
        if bare and bare <= {"compute-saturated", "site-dead", "circuit-open",
                             "offer-timeout", "domain-dead"}:
            # every candidate exists and would bind — the anchors are just
            # full (crashed, breaker-isolated, or unreachable over a lossy
            # east-west wire) right now. Eq. (12) keeps this distinct
            # from "no feasible binding": the remediation is retry/backoff
            # on an alternate anchor (or east-west spillover), not
            # relaxing the objectives.
            raise SessionError(
                FailureCause.COMPUTE_SCARCITY,
                f"all candidate sites saturated "
                f"({', '.join(sorted(reasons))})")
        raise SessionError(
            FailureCause.NO_FEASIBLE_BINDING,
            f"all candidates excluded ({', '.join(sorted(reasons))})")
    return k
