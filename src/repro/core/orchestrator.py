"""NE-AIaaS orchestrator: the end-to-end lifecycle facade (Fig. 1).

    establish(asp) = consent → DISCOVER → AI-PAGING → PREPARE → COMMIT
    serve(session, request)   — boundary telemetry + metering per request
    heartbeat(session)        — lease renewal + Eq. 14 migration triggers
    release(session)

Every phase runs under its Eq. (11) deadline and failures carry Eq. (12)
causes. The orchestrator owns the role composition (exposure/catalog/
execution/transport/analytics) but no business logic of its own — each
procedure lives in its module and is individually testable.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.analytics import Analytics
from repro.core.asp import ASP
from repro.core.catalog import Catalog, default_catalog
from repro.core.clock import Clock
from repro.core.discovery import discover
from repro.core.failures import FailureCause, SessionError, Timers
from repro.core.migration import (MigrationController, MigrationOutcome,
                                  MigrationTriggers, PlaneTransferPath)
from repro.core.paging import page
from repro.core.policy import PolicyControl
from repro.core.predictors import Predictors
from repro.core.qos import QoSFlowManager
from repro.core.session import AISession, SessionState
from repro.core.sites import ExecutionSite, default_sites
from repro.core.telemetry import BoundaryTelemetry, RequestRecord
from repro.core.twophase import TwoPhaseCoordinator
from repro.netfault.breaker import BreakerBoard


@dataclass
class ServeResult:
    text_tokens: int
    ttfb_ms: float
    latency_ms: float
    completed: bool
    queue_wait_ms: float = 0.0
    failed: Optional[FailureCause] = None
    request_id: str = ""
    klass: str = ""                    # QoS class the request rode
    token_ids: Optional[list] = None   # real-engine backends only


@dataclass
class ReanchorOutcome:
    """Result of one crash-recovery re-anchoring (supervisor path)."""
    ok: bool
    from_site: str
    to_site: Optional[str] = None
    #: the new anchor resumed the session's state from the hibernation
    #: store (host memory survives an engine crash); False = fresh context
    restored: bool = False
    cause: Optional[FailureCause] = None


class Orchestrator:
    def __init__(self, clock: Optional[Clock] = None,
                 catalog: Optional[Catalog] = None,
                 sites: Optional[Dict[str, ExecutionSite]] = None,
                 timers: Optional[Timers] = None):
        self.clock = clock or Clock()
        self.catalog = catalog or default_catalog()
        hosted = self.catalog.keys()
        self.sites = sites or default_sites(self.clock, hosted)
        self.qos = QoSFlowManager(self.clock)
        self.policy = PolicyControl(self.clock)
        self.analytics = Analytics(self.clock)
        self.predictors = Predictors(self.analytics)
        self.timers = timers or Timers()
        self.coordinator = TwoPhaseCoordinator(self.clock, self.sites,
                                               self.qos, self.timers)
        #: per-site circuit breakers (closed → open → half-open): fed by
        #: the site supervisors' probe outcomes; DISCOVER excludes open
        #: targets with the attributable reason ``circuit-open`` and the
        #: half-open transition probes them back in
        self.breakers = BreakerBoard(self.clock)
        self.migrations = MigrationController(
            self.clock, self.coordinator, self.catalog, self.sites,
            self.predictors, self.timers, analytics=self.analytics)
        # migration rides the REAL serving-plane data plane by default:
        # export/import between the sites' backends with fingerprint
        # verification and mid-stream handover (real engines and the
        # SimulatedEngine §V arm speak the same slot protocol)
        self.migrations.transfer_fn = PlaneTransferPath(
            self.plane_for, clock=self.clock)
        self.telemetry: Dict[str, BoundaryTelemetry] = {}
        self.sessions: Dict[str, AISession] = {}
        #: callables ``(site, PlaneResult)`` notified for every result the
        #: single recorder drains — the northbound gateway subscribes here
        #: so async completions reach the invoker whichever path pops them
        self.result_sinks: list = []
        #: set by a federation DomainController: this orchestrator becomes
        #: the HOME core of that domain — DISCOVER merges east-west offers
        #: (home-first) and PREPARE/COMMIT route cross-domain for remote
        #: candidates. None ⇒ single-domain behaviour, unchanged.
        self.federation = None
        #: set by a splitserve SplitManager: establishment may realize an
        #: ASP as a TWO-anchor split (edge draft + verify) when the ASP's
        #: split_policy allows it. None ⇒ single-anchor only, unchanged.
        self.splits = None
        #: callables ``(session_id, event, detail)`` notified on split
        #: quality-tier transitions (established/degraded/recovered/
        #: collapsed/verify-migrated) — the gateway subscribes here so
        #: tier changes reach the invoker as SessionEvents
        self.split_event_sinks: list = []

    # ------------------------------------------------------------------
    # stepwise lifecycle procedures — each northbound-drivable on its own;
    # establish() composes them under the Eq. (11) deadline chain
    # ------------------------------------------------------------------
    def begin_session(self, asp: ASP, invoker: str, zone: str) -> AISession:
        """Create the AIS record and bind consent (R7) before any
        reservation is attempted."""
        self.timers.validate(asp.objectives.t_max_ms / 1e3)
        session = AISession(asp, invoker, zone, self.clock,
                            sites=self.sites, qos=self.qos,
                            policy=self.policy)
        self.sessions[session.session_id] = session
        session.authz_ref = self.policy.grant_consent(
            invoker, asp.allowed_regions)
        return session

    def discover_for(self, session: AISession) -> list:
        """DISCOVER (Eq. 7/8): annotated candidate set under τ_disc. With
        a federation attached, this is home-routed: local candidates first,
        east-west offers merged in (per the domain's solicit policy) with
        exclusion reasons prefixed by the owning domain."""
        t0 = self.clock.now()
        cands = discover(session.asp, self.catalog, self.sites,
                         self.predictors, session.zone,
                         analytics=self.analytics, breakers=self.breakers)
        if self.federation is not None:
            cands = self.federation.augment(session, cands)
        if self.clock.now() - t0 > self.timers.tau_disc:
            raise SessionError(FailureCause.DEADLINE_EXPIRY,
                               "DISCOVER exceeded τ_disc")
        session.mark_discovered()
        return cands

    def page_for(self, session: AISession, cands: list,
                 exclude_sites: tuple = ()):
        """AI-PAGING (Eq. 9) + policy admission against the chosen anchor."""
        chosen = page(session.asp, cands, exclude_sites=exclude_sites)
        session.mark_anchored()
        # cost-envelope admission (policy role)
        self.policy.admit_cost(session.asp, chosen.prediction.cost_per_1k)
        # sovereignty re-check against the concrete site (consent scope);
        # east-west offers carry the region — the remote site table doesn't
        # exist here
        region = chosen.region or self.sites[chosen.site_id].spec.region
        self.policy.check_region(session.authz_ref, region)
        return chosen

    def prepare_for(self, session: AISession, chosen):
        """PREPARE: provisional co-reservation on both planes (2PC stage 1).
        A remote candidate routes the compute half east-west; the home
        domain keeps only its transport share."""
        self._check_adapter_binding(session, chosen)
        session.mark_preparing()
        if self.federation is not None and self.federation.is_remote(chosen):
            prepared = self.federation.prepare_remote(session, chosen)
        else:
            prepared = self.coordinator.prepare(
                chosen.model, chosen.site_id, session.zone, chosen.klass,
                slots=1, cache_bytes=chosen.model.session_state_bytes(2048))
        session.mark_prepared()
        return prepared

    def _check_adapter_binding(self, session: AISession, chosen) -> None:
        """Fail fast at PREPARE when the ASP names an adapter this
        catalog cannot resolve, or one whose base does not match the
        chosen model (outside the declared fallback ladder). Without
        this the unknown id would ride all the way to the engine bind
        and surface as an opaque serve failure."""
        aid = session.asp.adapter_id
        if not aid:
            return
        try:
            spec = self.catalog.adapters.get(aid)
        except KeyError:
            raise SessionError(
                FailureCause.NO_FEASIBLE_BINDING,
                f"unknown adapter {aid!r}: not registered in the "
                f"catalog") from None
        ladder = {m for m, _ in session.asp.fallback_ladder}
        if chosen.model.model_id != spec.base_model_id \
                and chosen.model.model_id not in ladder:
            raise SessionError(
                FailureCause.NO_FEASIBLE_BINDING,
                f"adapter {aid!r} targets base {spec.base_model_id!r}; "
                f"chosen model {chosen.model.model_id!r} is not its base "
                f"and not on the fallback ladder")

    def commit_for(self, session: AISession, chosen, prepared) -> AISession:
        """COMMIT: confirm both leases, bind, open charging + telemetry.
        For a cross-domain PREPARE the visited half stays provisional until
        this home COMMIT lands; failure on either side rolls both back."""
        if getattr(prepared, "is_federated", False):
            binding = self.federation.commit_remote(session, chosen,
                                                    prepared)
        else:
            binding = self.coordinator.commit(prepared, chosen.model)
        session.charging_ref = self.policy.open_charging(session.session_id)
        session.bind(binding)
        self.telemetry[session.session_id] = BoundaryTelemetry()
        return session

    def establish(self, asp: ASP, invoker: str, zone: str) -> AISession:
        """DISCOVER → PAGING → PREPARE/COMMIT under Eq. (11) deadlines."""
        session = self.begin_session(asp, invoker, zone)
        try:
            # split establishment first when the ASP consents: "require"
            # propagates any refusal; "auto" falls through to the normal
            # single-anchor path when no feasible split exists
            if self.splits is not None \
                    and asp.split_policy != "never" \
                    and self.splits.try_establish(session):
                return session
            cands = self.discover_for(session)
            chosen = self.page_for(session, cands)
            prepared = self.prepare_for(session, chosen)
            self.commit_for(session, chosen, prepared)
            return session
        except SessionError as e:
            session.fail(e.cause, str(e))
            raise

    # ------------------------------------------------------------------
    # serving plane plumbing
    # ------------------------------------------------------------------
    def plane_for(self, site) -> "ServingPlane":
        """The QoS-scheduled serving plane of one site. Real-engine planes
        are attached by AIaaSServer / launch.serve; absent those, a
        predictor-backed SimulatedEngine plane is created lazily so the
        control plane ALWAYS serves through the same scheduled path."""
        if getattr(site, "is_guest_view", False):
            return site.plane        # ensured by the owning domain's core
        if site.plane is None:
            from repro.serving.plane import ServingPlane, SimulatedEngine
            site.attach_plane(ServingPlane(
                self.clock, SimulatedEngine(self.clock),
                slots=site.spec.decode_slots,
                site_id=site.spec.site_id))
        return site.plane

    def qos_class(self, session: AISession):
        """TransportClass of the session's committed QoS flow — derived from
        the binding's QFI lease, not re-guessed from the tier."""
        from repro.core.qos import PREMIUM, BEST_EFFORT
        lease = self.qos.get(session.binding.qos_lease_id)
        if lease is not None:
            return lease.klass
        return PREMIUM if session.asp.tier >= 2 else BEST_EFFORT

    def record_results(self, site) -> list:
        """Drain the site plane's completed requests into boundary telemetry
        and charging — exactly once per request, for every session; returns
        the drained PlaneResults. This is the ONLY recorder: AIaaSServer
        and heartbeat both delegate here, so a request is billed identically
        whichever path pops it first. A guest view delegates to the OWNING
        domain's recorder (which meters wholesale and forwards roaming
        results home) so two domains never race on one plane's results."""
        if getattr(site, "is_guest_view", False):
            return site.record_results()
        plane = site.plane
        if plane is None:
            return []
        popped = plane.pop_results()
        for res in popped:
            self._record_one(site, res)
        return popped

    def _record_one(self, site, res, *, price_override=None) -> None:
        """Record ONE drained PlaneResult: telemetry, context accounting,
        charging, result sinks. ``price_override`` replaces the catalog
        price for roaming sessions whose model lives in another domain's
        catalog (the retail price from the accepted east-west offer)."""
        session = self.sessions.get(res.session_id)
        if session is None:
            return
        tele = self.telemetry.get(res.session_id)
        if tele is not None:
            tele.record(RequestRecord(
                t_submit=self.clock.now() - res.latency_ms / 1e3,
                ttfb_ms=res.ttfb_ms, latency_ms=res.latency_ms,
                completed=res.completed, tokens=res.tokens,
                queue_ms=res.queue_wait_ms))
        # context accounting: the session's actual served context sizes
        # any later migration payload / PREPARE cache reservation
        if res.tokens:
            session.note_context(res.prompt_tokens + res.tokens)
        if session.charging_ref is not None and res.tokens:
            b = session.binding
            if price_override is not None:
                price = price_override
            else:
                model = self._model_entry(b)
                price = model.price_per_1k_tokens if model else 0.0
            # chip time = slot occupancy only; queue wait is not billed
            service_s = max(res.latency_ms - res.queue_wait_ms, 0.0) / 1e3
            self.policy.meter(
                session.charging_ref, tokens=res.tokens,
                chip_s=service_s * site.spec.chips
                / max(site.spec.decode_slots, 1),
                unit_price=price)
        for sink in self.result_sinks:
            sink(site, res)

    # ------------------------------------------------------------------
    def _model_entry(self, binding):
        """The binding's ModelEntry, or None when the session roams on a
        model this domain's catalog does not carry (predictor hints and
        catalog pricing degrade gracefully; the visited domain holds the
        authoritative entry)."""
        if binding is None:
            return None
        try:
            return self.catalog.get(binding.model_id, binding.model_version)
        except KeyError:
            return None

    # ------------------------------------------------------------------
    def _service_hints(self, session: AISession, plane, model, site, klass,
                       prompt_tokens: int, gen_tokens: int):
        """Predictor-supplied (ttfb, total) service-time hints, only for
        backends that declare they need them (capability check, not
        type-sniffing of serving internals)."""
        if model is None or \
                not getattr(plane.backend, "needs_service_hints", False):
            return None, None
        pred = self.predictors.predict(session.asp, model, site,
                                       session.zone, klass,
                                       prompt_tokens=prompt_tokens,
                                       gen_tokens=gen_tokens)
        return (pred.t_ff_ms,
                pred.t_ff_ms + gen_tokens * pred.decode_ms_per_token)

    def _serve_checked(self, session: AISession):
        """Common serve-side admission: Eq. (6) consent + committed domain;
        returns (site, model, plane, klass) for the session's anchor."""
        if not session.serve_allowed():
            if not session.v_sigma():
                raise SessionError(FailureCause.CONSENT_VIOLATION,
                                   "consent revoked ⇒ ServeDisabled (Eq. 6)")
            raise SessionError(FailureCause.DEADLINE_EXPIRY,
                               "session not in committed domain")
        b = session.binding
        site = self.sites[b.site_id]
        return (site, self._model_entry(b), self.plane_for(site),
                self.qos_class(session))

    # ------------------------------------------------------------------
    def _effective_t_max(self, session: AISession,
                         deadline_ms: Optional[float]) -> float:
        """Per-request deadline for the plane's fast-fail admission: the
        ASP bound, shrunk to the caller's remaining ``deadline_ms`` budget
        when one was propagated — a hop never queues work it cannot
        finish in the budget that is actually left."""
        t_max = session.asp.objectives.t_max_ms
        if deadline_ms is not None:
            t_max = min(t_max, deadline_ms)
        return t_max

    def submit(self, session: AISession, *, prompt_tokens: int = 512,
               gen_tokens: int = 64, prompt=None,
               request_id: Optional[str] = None,
               deadline_ms: Optional[float] = None):
        """Async path: enqueue one request on the anchor plane without
        driving it (batched serving / open-loop simulation); returns the
        scheduler Request, or None when admission control rejects it.
        Completions surface through ``record_results`` → ``result_sinks``."""
        site, model, plane, klass = self._serve_checked(session)
        hint_ttfb, hint_total = self._service_hints(
            session, plane, model, site, klass, prompt_tokens, gen_tokens)
        return plane.submit(
            session_id=session.session_id, klass=klass.name,
            prompt_tokens=prompt_tokens, gen_tokens=gen_tokens,
            t_max_ms=self._effective_t_max(session, deadline_ms),
            hint_ttfb_ms=hint_ttfb, hint_total_ms=hint_total,
            request_id=request_id, prompt=prompt,
            adapter_id=session.asp.adapter_id)

    # ------------------------------------------------------------------
    def serve(self, session: AISession, *, prompt_tokens: int = 512,
              gen_tokens: int = 64, prompt=None,
              request_id: Optional[str] = None,
              deadline_ms: Optional[float] = None) -> ServeResult:
        """One request through the anchor site's ServingPlane.

        The QoS class comes from the binding's QFI; admission is
        class-ordered with premium reservation and deadline fast-fail. With
        a real engine behind the plane this runs actual prefill/decode
        rounds (examples/); otherwise the SimulatedEngine backend uses
        predictor service times (control-plane tests). Either way the
        boundary telemetry and metering are identical — that's the
        falsifiability point.
        """
        site, model, plane, klass = self._serve_checked(session)
        hint_ttfb, hint_total = self._service_hints(
            session, plane, model, site, klass, prompt_tokens, gen_tokens)
        res = plane.serve(
            session_id=session.session_id, klass=klass.name,
            prompt_tokens=prompt_tokens, gen_tokens=gen_tokens,
            t_max_ms=self._effective_t_max(session, deadline_ms),
            request_id=request_id,
            hint_ttfb_ms=hint_ttfb, hint_total_ms=hint_total, prompt=prompt,
            adapter_id=session.asp.adapter_id)
        self.record_results(site)
        return ServeResult(res.tokens, res.ttfb_ms, res.latency_ms,
                           res.completed, queue_wait_ms=res.queue_wait_ms,
                           failed=res.failed, request_id=res.request_id,
                           klass=res.klass, token_ids=res.token_ids)

    # ------------------------------------------------------------------
    def heartbeat(self, session: AISession,
                  triggers: Optional[MigrationTriggers] = None
                  ) -> Optional[MigrationOutcome]:
        """Renew leases; fire Eq. (14) migration when risk crosses δ."""
        # heartbeat cadence doubles as the orphan sweep: provisional 2PC
        # leases whose COMMIT/ABORT was lost in flight are aborted once
        # their τ_prep + τ_com + hold window passes (timers are enforced)
        self.coordinator.reap()
        if session.state not in (SessionState.COMMITTED,
                                 SessionState.MIGRATING):
            return None
        session.renew(self.timers.lease_s)
        # consent is a bounded authorization with a sliding window: an
        # actively heartbeating session keeps its grant alive through the
        # same northbound surface that renews the leases; revoked grants
        # and sessions that stop heartbeating lapse (Eq. 6)
        self.policy.renew_consent(session.authz_ref)
        # a split session's SECOND (verify) anchor renews through the same
        # beat: lease lapse degrades to edge-only, collapsed acceptance
        # un-splits (both emit quality-tier events, never failures)
        if self.splits is not None:
            self.splits.heartbeat(session)
        site = self.sites[session.binding.site_id]
        # live congestion from the site's serving plane (NWDAF loop): queue
        # depth per slot and arrival rate are MEASURED, not assumed — this is
        # what makes paging (Eq. 9) and migration triggers (Eq. 14) react to
        # real load instead of static zeros.
        plane = site.plane
        load = plane.load() if plane is not None else None
        self.analytics.observe_site(
            site.spec.site_id, utilization=site.utilization(),
            queue_depth=load.queue_depth if load else 0.0,
            arrival_rate=load.arrival_rate if load else 0.0,
            page_util=getattr(load, "page_util", 0.0) if load else 0.0)
        if plane is not None:
            self.record_results(site)   # pick up async completions
        tele = self.telemetry.get(session.session_id)
        if tele and len(tele) >= 8:
            z = tele.snapshot()
            self.analytics.observe_latency(
                site.spec.site_id,
                f"{session.binding.model_id}@{session.binding.model_version}",
                z.q99_ms)
        trig = triggers or MigrationTriggers()
        if session.asp.continuity_required() and \
                self.migrations.check_trigger(session, session.zone, trig):
            return self.migrations.migrate(session, session.zone)
        return None

    # ------------------------------------------------------------------
    def reanchor(self, session: AISession, *, exclude_sites: tuple = (),
                 state_source=None) -> ReanchorOutcome:
        """AI-PAGING re-anchoring for a session orphaned by a site crash.

        Unlike ``migrations.migrate`` this never touches the old anchor —
        there is nothing to export from a dead engine. The session
        re-discovers (the dead site is excluded via the analytics
        ``site-dead`` verdict), re-prepares at a paged-in site under
        τ_mig, and binds; make-before-break degenerates to plain re-anchor
        because the old leases are already void. ``state_source`` is a
        surviving :class:`HibernationStore` (host memory outlives the
        engine process): when it holds the session's state, the new
        anchor's backend re-imports it so generation resumes bit-exactly;
        a corrupt or refused restore degrades to a fresh context rather
        than failing the re-anchor. On failure the session FAILs with the
        Eq. 12 cause (NO_FEASIBLE_BINDING / COMPUTE_SCARCITY /
        DEADLINE_EXPIRY), which is the attributable loss accounting the
        recovery bench measures."""
        src = session.binding.site_id if session.binding else ""
        excl = tuple(exclude_sites) or ((src,) if src else ())
        t0 = self.clock.now()
        try:
            if session.state is SessionState.COMMITTED:
                session.mark_migrating()
            elif session.state is not SessionState.MIGRATING:
                raise SessionError(
                    FailureCause.POLICY_DENIAL,
                    f"re-anchor from state {session.state.value}")
            if self.federation is not None:
                cands = self.federation.merged_discover(
                    session, session.zone, exclude_sites=excl)
            else:
                cands = discover(session.asp, self.catalog, self.sites,
                                 self.predictors, session.zone,
                                 analytics=self.analytics,
                                 breakers=self.breakers)
            target = page(session.asp, cands, exclude_sites=excl)
            region = target.region or self.sites[target.site_id].spec.region
            self.policy.check_region(session.authz_ref, region)
            ctx = self.migrations.context_tokens(session)
            remote = self.federation is not None \
                and self.federation.is_remote(target)
            if remote:
                prepared = self.federation.prepare_remote(
                    session, target, hold_s=self.timers.tau_mig,
                    context_tokens=ctx)
                binding = self.federation.commit_remote(session, target,
                                                        prepared)
            else:
                prepared = self.coordinator.prepare(
                    target.model, target.site_id, session.zone,
                    target.klass, slots=1,
                    cache_bytes=target.model.session_state_bytes(ctx),
                    hold_s=self.timers.tau_mig)
                binding = self.coordinator.commit(prepared, target.model)
            if self.clock.now() - t0 > self.timers.tau_mig:
                raise SessionError(FailureCause.DEADLINE_EXPIRY,
                                   "re-anchor deadline expired (τ_mig)")
            session.bind(binding)    # old leases void: release is a no-op
            restored = False
            if state_source is not None and not remote \
                    and state_source.has(session.session_id):
                restored = self._restore_state(session, target,
                                               state_source)
            session.history.append(
                (self.clock.now(), f"re-anchored:{src}->{target.site_id}"))
            return ReanchorOutcome(True, src, target.site_id, restored)
        except SessionError as e:
            session.fail(e.cause, str(e))
            return ReanchorOutcome(False, src, cause=e.cause)

    def _restore_state(self, session: AISession, target,
                       state_source) -> bool:
        """Best-effort state resume at the new anchor: verified restore →
        backend import → drop the store copy (only after the import holds
        it). Corruption (IOError) or target admission refusal leaves the
        session re-anchored with a fresh context."""
        backend = self.plane_for(self.sites[target.site_id]).backend
        if not hasattr(backend, "import_slot"):
            return False
        try:
            payload = state_source.restore(session.session_id)
            backend.import_slot(session.session_id, payload)
        except Exception:
            return False
        state_source.drop(session.session_id)
        return True

    # ------------------------------------------------------------------
    def compliance(self, session: AISession):
        tele = self.telemetry.get(session.session_id)
        return tele.compliance(session.asp) if tele else None

    def release(self, session: AISession) -> None:
        # free the anchor's data-plane session state (migrated-in slots,
        # SimulatedEngine serialized state) along with the leases — the
        # backend store must not grow with released sessions
        b = session.binding
        if b is not None:
            site = self.sites.get(b.site_id)
            plane = site.plane if site is not None else None
            if plane is not None and hasattr(plane.backend, "release_slot"):
                plane.backend.release_slot(session.session_id)
        # a split session also holds a verify half: free its leases too
        if self.splits is not None:
            self.splits.on_release(session)
        session.release()
