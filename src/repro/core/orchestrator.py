"""NE-AIaaS orchestrator: the end-to-end lifecycle facade (Fig. 1).

    establish(asp) = consent → DISCOVER → AI-PAGING → PREPARE → COMMIT
    serve(session, request)   — boundary telemetry + metering per request
    heartbeat(session)        — lease renewal + Eq. 14 migration triggers
    release(session)

Every phase runs under its Eq. (11) deadline and failures carry Eq. (12)
causes. The orchestrator owns the role composition (exposure/catalog/
execution/transport/analytics) but no business logic of its own — each
procedure lives in its module and is individually testable.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.analytics import Analytics
from repro.core.asp import ASP
from repro.core.catalog import Catalog, default_catalog
from repro.core.clock import Clock
from repro.core.discovery import discover
from repro.core.failures import FailureCause, SessionError, Timers
from repro.core.migration import (MigrationController, MigrationOutcome,
                                  MigrationTriggers)
from repro.core.paging import page
from repro.core.policy import PolicyControl
from repro.core.predictors import Predictors
from repro.core.qos import QoSFlowManager
from repro.core.session import AISession, SessionState
from repro.core.sites import ExecutionSite, default_sites
from repro.core.telemetry import BoundaryTelemetry, RequestRecord
from repro.core.twophase import TwoPhaseCoordinator


@dataclass
class ServeResult:
    text_tokens: int
    ttfb_ms: float
    latency_ms: float
    completed: bool


class Orchestrator:
    def __init__(self, clock: Optional[Clock] = None,
                 catalog: Optional[Catalog] = None,
                 sites: Optional[Dict[str, ExecutionSite]] = None,
                 timers: Optional[Timers] = None):
        self.clock = clock or Clock()
        self.catalog = catalog or default_catalog()
        hosted = tuple(self.catalog._entries.keys())
        self.sites = sites or default_sites(self.clock, hosted)
        self.qos = QoSFlowManager(self.clock)
        self.policy = PolicyControl(self.clock)
        self.analytics = Analytics(self.clock)
        self.predictors = Predictors(self.analytics)
        self.timers = timers or Timers()
        self.coordinator = TwoPhaseCoordinator(self.clock, self.sites,
                                               self.qos, self.timers)
        self.migrations = MigrationController(
            self.clock, self.coordinator, self.catalog, self.sites,
            self.predictors, self.timers, analytics=self.analytics)
        self.telemetry: Dict[str, BoundaryTelemetry] = {}
        self.sessions: Dict[str, AISession] = {}

    # ------------------------------------------------------------------
    def establish(self, asp: ASP, invoker: str, zone: str) -> AISession:
        """DISCOVER → PAGING → PREPARE/COMMIT under Eq. (11) deadlines."""
        self.timers.validate(asp.objectives.t_max_ms / 1e3)
        session = AISession(asp, invoker, zone, self.clock,
                            sites=self.sites, qos=self.qos,
                            policy=self.policy)
        self.sessions[session.session_id] = session
        try:
            # consent/authorization binding (R7) precedes any reservation
            session.authz_ref = self.policy.grant_consent(
                invoker, asp.allowed_regions)
            t0 = self.clock.now()
            cands = discover(asp, self.catalog, self.sites, self.predictors,
                             zone, analytics=self.analytics)
            if self.clock.now() - t0 > self.timers.tau_disc:
                raise SessionError(FailureCause.DEADLINE_EXPIRY,
                                   "DISCOVER exceeded τ_disc")
            session.mark_discovered()
            chosen = page(asp, cands)
            session.mark_anchored()
            # cost-envelope admission (policy role)
            self.policy.admit_cost(asp, chosen.prediction.cost_per_1k)
            # sovereignty re-check against the concrete site (consent scope)
            self.policy.check_region(
                session.authz_ref,
                self.sites[chosen.site_id].spec.region)
            session.mark_preparing()
            prepared = self.coordinator.prepare(
                chosen.model, chosen.site_id, zone, chosen.klass, slots=1,
                cache_bytes=chosen.model.session_state_bytes(2048))
            session.mark_prepared()
            binding = self.coordinator.commit(prepared, chosen.model)
            session.charging_ref = self.policy.open_charging(
                session.session_id)
            session.bind(binding)
            self.telemetry[session.session_id] = BoundaryTelemetry()
            return session
        except SessionError as e:
            session.fail(e.cause, str(e))
            raise

    # ------------------------------------------------------------------
    def serve(self, session: AISession, *, prompt_tokens: int = 512,
              gen_tokens: int = 64) -> ServeResult:
        """One request on the session's committed binding.

        With a real engine attached to the anchor site this runs actual
        prefill/decode (examples/); otherwise service time comes from the
        predictors (control-plane tests). Either way the boundary telemetry
        and metering are identical — that's the falsifiability point.
        """
        if not session.serve_allowed():
            if not session.v_sigma():
                raise SessionError(FailureCause.CONSENT_VIOLATION,
                                   "consent revoked ⇒ ServeDisabled (Eq. 6)")
            raise SessionError(FailureCause.DEADLINE_EXPIRY,
                               "session not in committed domain")
        b = session.binding
        site = self.sites[b.site_id]
        model = self.catalog.get(b.model_id, b.model_version)
        t_start = self.clock.now()
        if site.engine is not None:
            out = site.engine.serve(session.session_id, prompt_tokens,
                                    gen_tokens)
            ttfb_ms, total_ms = out["ttfb_ms"], out["latency_ms"]
        else:
            from repro.core.qos import PREMIUM, BEST_EFFORT
            klass = PREMIUM if session.asp.tier >= 2 else BEST_EFFORT
            pred = self.predictors.predict(session.asp, model, site,
                                           session.zone, klass,
                                           prompt_tokens=prompt_tokens,
                                           gen_tokens=gen_tokens)
            ttfb_ms = pred.t_ff_ms
            total_ms = pred.t_ff_ms + gen_tokens * pred.decode_ms_per_token
            self.clock.sleep(total_ms / 1e3)
        completed = total_ms <= session.asp.objectives.t_max_ms
        self.telemetry[session.session_id].record(RequestRecord(
            t_submit=t_start, ttfb_ms=ttfb_ms, latency_ms=total_ms,
            completed=completed, tokens=gen_tokens))
        self.policy.meter(session.charging_ref, tokens=gen_tokens,
                          chip_s=total_ms / 1e3 * site.spec.chips
                          / max(site.spec.decode_slots, 1),
                          unit_price=model.price_per_1k_tokens)
        return ServeResult(gen_tokens, ttfb_ms, total_ms, completed)

    # ------------------------------------------------------------------
    def heartbeat(self, session: AISession,
                  triggers: Optional[MigrationTriggers] = None
                  ) -> Optional[MigrationOutcome]:
        """Renew leases; fire Eq. (14) migration when risk crosses δ."""
        if session.state not in (SessionState.COMMITTED,
                                 SessionState.MIGRATING):
            return None
        session.renew(self.timers.lease_s)
        site = self.sites[session.binding.site_id]
        self.analytics.observe_site(
            site.spec.site_id, utilization=site.utilization(),
            queue_depth=0.0, arrival_rate=0.0)
        tele = self.telemetry.get(session.session_id)
        if tele and len(tele) >= 8:
            z = tele.snapshot()
            self.analytics.observe_latency(
                site.spec.site_id,
                f"{session.binding.model_id}@{session.binding.model_version}",
                z.q99_ms)
        trig = triggers or MigrationTriggers()
        if session.asp.continuity_required() and \
                self.migrations.check_trigger(session, session.zone, trig):
            return self.migrations.migrate(session, session.zone)
        return None

    # ------------------------------------------------------------------
    def compliance(self, session: AISession):
        tele = self.telemetry.get(session.session_id)
        return tele.compliance(session.asp) if tele else None

    def release(self, session: AISession) -> None:
        session.release()
