"""Model catalog (the "catalog role", Section IV-A): resolvable model
identity + admissibility constraints, so discovery outputs are auditable and
never degenerate to opaque endpoint lists.

Each entry carries the *measured* hardware footprint used by the predictors:
FLOPs/bytes per token come from the analytic model or, when a dry-run
artifact exists for the arch, from the compiled cost analysis — tying
discovery ranking (Eq. 7/8) to the roofline numbers in EXPERIMENTS.md.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.adapters.catalog import AdapterCatalog, AdapterSpec, version_key
from repro.core.asp import ASP, Modality, QualityTier
from repro.models.config import ModelConfig
from repro.models.kvcache import cache_bytes


#: modality → admissible model families (constraint (a) of the ASP)
MODALITY_FAMILIES = {
    Modality.TEXT_GEN: ("dense", "moe", "hybrid", "ssm"),
    Modality.CODE_GEN: ("dense", "moe"),
    Modality.VISION_TEXT: ("dense",),          # + frontend == vision
    Modality.SPEECH_TRANSLATION: ("encdec",),
    Modality.EMBEDDING: ("dense", "encdec"),
}


@dataclass(frozen=True)
class ModelEntry:
    model_id: str
    version: str
    cfg: ModelConfig
    tier: QualityTier
    modalities: Tuple[Modality, ...]
    #: sovereignty tags: regions whose data this model is licensed to process
    regions: Tuple[str, ...] = ("eu", "us", "apac")
    #: price (currency-units) per 1k generated tokens at this tier
    price_per_1k_tokens: float = 0.5

    # -- hardware footprint (per token unless noted) ---------------------
    @property
    def active_params(self) -> int:
        return self.cfg.active_param_count()

    @property
    def param_bytes(self) -> int:
        return self.cfg.param_count() * 2  # bf16 serving weights

    def decode_flops_per_token(self) -> float:
        return 2.0 * self.active_params

    def prefill_flops_per_token(self) -> float:
        return 2.0 * self.active_params

    def decode_bytes_per_token(self, context: int, batch_hint: int = 8) -> float:
        """HBM traffic per generated token ≈ params + this session's share of
        the KV/state read (decode is memory-bound; the batch amortises
        weights)."""
        kv = cache_bytes(self.cfg, 1, context)
        return self.param_bytes / max(batch_hint, 1) + kv

    def session_state_bytes(self, context: int) -> int:
        """Migration payload size (make-before-break transfer)."""
        return cache_bytes(self.cfg, 1, context)

    def matches(self, asp: ASP) -> bool:
        if asp.modality not in self.modalities:
            return False
        if self.tier < asp.tier:
            return False
        fams = MODALITY_FAMILIES[asp.modality]
        if self.cfg.family not in fams:
            return False
        if asp.modality is Modality.VISION_TEXT and self.cfg.frontend != "vision":
            return False
        return True


class Catalog:
    def __init__(self):
        self._entries: Dict[str, ModelEntry] = {}
        #: versioned LoRA adapters registered against base models here
        self.adapters = AdapterCatalog()

    def register(self, entry: ModelEntry) -> None:
        key = f"{entry.model_id}@{entry.version}"
        if key in self._entries:
            raise ValueError(f"duplicate catalog entry {key}")
        self._entries[key] = entry

    def register_adapter(self, spec: AdapterSpec, weights=None) -> AdapterSpec:
        """Register a tenant adapter against its base model. The base
        must already be registered; deterministic weights are
        materialised from the base's d_model when none are supplied."""
        try:
            base = self.get(spec.base_model_id, spec.base_model_version)
        except KeyError:
            raise ValueError(
                f"adapter {spec.key} targets unregistered base "
                f"{spec.base_key()}")
        return self.adapters.register(
            spec, weights, d_model=base.cfg.d_model)

    def get(self, model_id: str, version: Optional[str] = None) -> ModelEntry:
        if version:
            return self._entries[f"{model_id}@{version}"]
        matches = [e for e in self._entries.values() if e.model_id == model_id]
        if not matches:
            raise KeyError(model_id)
        # numeric-aware: "10.0" must outrank "9.0" deterministically
        return sorted(matches, key=lambda e: version_key(e.version))[-1]

    def keys(self):
        """All registered model keys ("model_id@version")."""
        return tuple(self._entries.keys())

    def entries(self):
        """All registered ModelEntry records."""
        return tuple(self._entries.values())

    def admissible(self, asp: ASP):
        """All entries whose constraints admit this ASP (hard filter of
        Eq. 7 — ranking happens in discovery)."""
        out = [e for e in self._entries.values() if e.matches(asp)]
        # honour the fallback ladder ordering when given
        if asp.fallback_ladder:
            order = {m: i for i, (m, _) in enumerate(asp.fallback_ladder)}
            out.sort(key=lambda e: order.get(e.model_id, len(order)))
        return out

    def __len__(self):
        return len(self._entries)


def default_catalog() -> Catalog:
    """Catalog with all assigned architectures registered at sensible tiers."""
    from repro.configs import ARCH_IDS, get_config

    tiers = {
        "qwen2-vl-72b": QualityTier.PREMIUM,
        "command-r-35b": QualityTier.PREMIUM,
        "qwen3-moe-30b-a3b": QualityTier.PREMIUM,
        "phi3-medium-14b": QualityTier.STANDARD,
        "mixtral-8x7b": QualityTier.STANDARD,
        "minitron-8b": QualityTier.STANDARD,
        "codeqwen1.5-7b": QualityTier.STANDARD,
        "recurrentgemma-2b": QualityTier.BASIC,
        "mamba2-1.3b": QualityTier.BASIC,
        "seamless-m4t-medium": QualityTier.STANDARD,
        "edge-tiny": QualityTier.BASIC,
    }
    mods = {
        "qwen2-vl-72b": (Modality.VISION_TEXT, Modality.TEXT_GEN),
        "seamless-m4t-medium": (Modality.SPEECH_TRANSLATION,),
        "codeqwen1.5-7b": (Modality.CODE_GEN, Modality.TEXT_GEN),
    }
    cat = Catalog()
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        price = 0.05 + 0.05 * (cfg.active_param_count() / 1e9)
        cat.register(ModelEntry(
            model_id=arch, version="1.0", cfg=cfg, tier=tiers[arch],
            modalities=mods.get(arch, (Modality.TEXT_GEN,)),
            price_per_1k_tokens=round(price, 3)))
    return cat
