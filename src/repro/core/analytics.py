"""Analytics role (NWDAF-style): measured feasibility signals ξ.

Maintains exponentially-smoothed load / queue / latency observations per
(site, model) and mobility risk per invoker, and exposes the coarse context
summary ξ that conditions anchoring (Eq. 9) and migration triggers (Eq. 14).
Nothing here is a static assumption: every field is updated from telemetry
(serving) or from the simulator's generated load — "admission ... derived
from measured feasibility rather than static assumptions" (§II-A).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

from repro.core.clock import Clock


class EWMA:
    def __init__(self, alpha: float = 0.2, init: float = 0.0):
        self.alpha = alpha
        self.value = init
        self.n = 0

    def update(self, x: float) -> float:
        self.n += 1
        a = self.alpha if self.n > 1 else 1.0
        self.value = (1 - a) * self.value + a * x
        return self.value


@dataclass
class SiteContext:
    """ξ restricted to one site: coarse, privacy-preserving summaries."""
    utilization: float = 0.0        # decode-slot occupancy [0, 1]
    queue_depth: float = 0.0        # waiting requests per slot
    arrival_rate: float = 0.0       # admitted sessions / s
    p99_infer_ms: float = 0.0       # measured execution-side p99
    page_util: float = 0.0          # KV page-pool occupancy [0, 1]
    healthy: bool = True
    alive: bool = True              # supervisor liveness (dead = crashed)


class Analytics:
    def __init__(self, clock: Clock):
        self.clock = clock
        self._util: Dict[str, EWMA] = {}
        self._queue: Dict[str, EWMA] = {}
        self._rate: Dict[str, EWMA] = {}
        self._mem: Dict[str, EWMA] = {}        # site -> KV page-pool util
        self._p99: Dict[Tuple[str, str], EWMA] = {}
        self._mobility: Dict[str, EWMA] = {}   # invoker -> handover rate /s
        self._deny: set = set()                # A1-style site deny list
        self._dead: set = set()                # supervisor-declared crashes
        #: per-site load epoch: bumped whenever NEW evidence about a site
        #: arrives (heartbeat load, measured latency, A1 policy) — the
        #: invalidation key for predictor memoization
        self._epochs: Dict[str, int] = {}

    def _bump(self, site_id: str) -> None:
        self._epochs[site_id] = self._epochs.get(site_id, 0) + 1

    def load_epoch(self, site_id: str) -> int:
        """Monotone counter of ξ updates for one site. Predictions cached
        at epoch k are valid until the next observation arrives."""
        return self._epochs.get(site_id, 0)

    # -- ingestion -------------------------------------------------------
    def observe_site(self, site_id: str, *, utilization: float,
                     queue_depth: float, arrival_rate: float,
                     page_util: float = 0.0) -> None:
        self._util.setdefault(site_id, EWMA()).update(utilization)
        self._queue.setdefault(site_id, EWMA()).update(queue_depth)
        self._rate.setdefault(site_id, EWMA()).update(arrival_rate)
        self._mem.setdefault(site_id, EWMA()).update(page_util)
        self._bump(site_id)

    def observe_latency(self, site_id: str, model_key: str, p99_ms: float) -> None:
        self._p99.setdefault((site_id, model_key), EWMA()).update(p99_ms)
        self._bump(site_id)

    def observe_handover(self, invoker: str, rate_per_s: float) -> None:
        self._mobility.setdefault(invoker, EWMA(alpha=0.3)).update(rate_per_s)

    def deny_site(self, site_id: str) -> None:
        """A1-style policy guidance: steer away from this site."""
        self._deny.add(site_id)
        self._bump(site_id)

    def allow_site(self, site_id: str) -> None:
        self._deny.discard(site_id)
        self._bump(site_id)

    def mark_site_dead(self, site_id: str) -> None:
        """Supervisor crash verdict: the site is excluded from DISCOVER
        (reason ``site-dead``) until marked alive again."""
        self._dead.add(site_id)
        self._bump(site_id)

    def mark_site_alive(self, site_id: str) -> None:
        self._dead.discard(site_id)
        self._bump(site_id)

    def site_alive(self, site_id: str) -> bool:
        return site_id not in self._dead

    # -- ξ exposure ---------------------------------------------------------
    def site_context(self, site_id: str) -> SiteContext:
        return SiteContext(
            utilization=self._util.get(site_id, EWMA()).value,
            queue_depth=self._queue.get(site_id, EWMA()).value,
            arrival_rate=self._rate.get(site_id, EWMA()).value,
            p99_infer_ms=self._p99.get((site_id, "*"), EWMA()).value,
            page_util=self._mem.get(site_id, EWMA()).value,
            healthy=site_id not in self._deny and site_id not in self._dead,
            alive=site_id not in self._dead,
        )

    def measured_p99(self, site_id: str, model_key: str) -> float | None:
        e = self._p99.get((site_id, model_key))
        return e.value if e and e.n > 3 else None

    def handover_rate(self, invoker: str) -> float:
        e = self._mobility.get(invoker)
        return e.value if e else 0.0
