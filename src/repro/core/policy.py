"""Policy role (PCC-style): consent/authorization scope (R7), session-scoped
charging (R8), cost-envelope admission, and A1-style steering constraints.

Consent (resource-owner authorization, CAPIF RNAA direction): an authz grant
names the invoker, the data classes the session may process, and the regions
processing may occur in. Revocation takes effect immediately — the session's
``serve_allowed`` consults this registry on every call (Eq. 6).

Charging: every served request is metered against the session's charging
reference, giving deterministic attribution (R8) and enforcement of the ASP
cost envelope.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.asp import ASP
from repro.core.clock import Clock
from repro.core.failures import FailureCause, SessionError


@dataclass
class ConsentGrant:
    authz_ref: str
    invoker: str
    allowed_regions: Tuple[str, ...]
    data_classes: Tuple[str, ...] = ("prompt", "generated")
    revoked: bool = False
    #: absolute lapse time (clock domain of PolicyControl). Consent is a
    #: *bounded* authorization: a grant that outlives its TTL lapses
    #: exactly like a revocation — the serve path's Eq. (6) re-check maps
    #: it to CONSENT_VIOLATION mid-session.
    expires_at: float = float("inf")

    def valid(self, now: float) -> bool:
        return not self.revoked and now < self.expires_at


@dataclass
class ChargingRecord:
    charging_ref: str
    session_id: str
    tokens: int = 0
    chip_s: float = 0.0
    cost: float = 0.0
    events: list = field(default_factory=list)


class PolicyControl:
    #: default consent TTL (seconds) — every grant is clock-bounded unless
    #: the caller passes an explicit ttl_s
    DEFAULT_CONSENT_TTL_S = 3600.0

    def __init__(self, clock: Clock, *,
                 consent_ttl_s: Optional[float] = None):
        self.clock = clock
        self.consent_ttl_s = consent_ttl_s if consent_ttl_s is not None \
            else self.DEFAULT_CONSENT_TTL_S
        self._grants: Dict[str, ConsentGrant] = {}
        self._charges: Dict[str, ChargingRecord] = {}
        self._ids = itertools.count(1)

    # -- consent (v_σ) ----------------------------------------------------
    def grant_consent(self, invoker: str, regions: Tuple[str, ...],
                      ttl_s: Optional[float] = None) -> str:
        ref = f"authz-{next(self._ids):06d}"
        ttl = ttl_s if ttl_s is not None else self.consent_ttl_s
        self._grants[ref] = ConsentGrant(
            ref, invoker, tuple(regions),
            expires_at=self.clock.now() + ttl)
        return ref

    def revoke(self, authz_ref: str) -> None:
        g = self._grants.get(authz_ref)
        if g:
            g.revoked = True

    def renew_consent(self, authz_ref: str,
                      ttl_s: Optional[float] = None) -> bool:
        """Re-authorize (extend) a live grant; a revoked or lapsed grant
        cannot be renewed — the invoker must re-acquire authorization."""
        g = self._grants.get(authz_ref)
        if g is None or not g.valid(self.clock.now()):
            return False
        g.expires_at = self.clock.now() + \
            (ttl_s if ttl_s is not None else self.consent_ttl_s)
        return True

    def consent_valid(self, authz_ref: Optional[str]) -> bool:
        if authz_ref is None:
            return False
        g = self._grants.get(authz_ref)
        return bool(g and g.valid(self.clock.now()))

    def check_region(self, authz_ref: str, region: str) -> None:
        g = self._grants.get(authz_ref)
        if g is None or not g.valid(self.clock.now()):
            raise SessionError(FailureCause.CONSENT_VIOLATION,
                               "no valid consent grant")
        if region not in g.allowed_regions:
            raise SessionError(
                FailureCause.SOVEREIGNTY_VIOLATION,
                f"region {region!r} outside consented scope {g.allowed_regions}")

    # -- admission policy ------------------------------------------------
    def admit_cost(self, asp: ASP, predicted_cost_per_1k: float) -> None:
        if predicted_cost_per_1k > asp.max_cost_per_1k_tokens:
            raise SessionError(
                FailureCause.POLICY_DENIAL,
                f"predicted cost {predicted_cost_per_1k:.3f}/1k exceeds "
                f"envelope {asp.max_cost_per_1k_tokens:.3f}/1k")

    # -- charging (R8) --------------------------------------------------------
    def open_charging(self, session_id: str) -> str:
        ref = f"chg-{next(self._ids):06d}"
        self._charges[ref] = ChargingRecord(ref, session_id)
        return ref

    def meter(self, charging_ref: str, *, tokens: int, chip_s: float,
              unit_price: float) -> None:
        rec = self._charges.get(charging_ref)
        if rec is None:
            raise SessionError(FailureCause.POLICY_DENIAL,
                               f"unknown charging ref {charging_ref}")
        rec.tokens += tokens
        rec.chip_s += chip_s
        rec.cost += tokens / 1000.0 * unit_price
        rec.events.append((self.clock.now(), tokens, chip_s))

    def charging(self, charging_ref: str) -> ChargingRecord:
        return self._charges[charging_ref]
