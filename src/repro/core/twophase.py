"""Two-phase PREPARE/COMMIT — atomic co-reservation of compute and QoS.

Correctness requirements implemented here (Section IV-B):

* **No partial allocation is representable**: PREPARE obtains *provisional*
  leases on both planes; if either PREPARE fails, the other is rolled back
  before the error propagates. COMMIT confirms both or releases both.
* **Explicit deadlines** (Eq. 11): each phase runs under its τ; expiry maps
  to FailureCause.DEADLINE_EXPIRY, scarcity maps to COMPUTE_SCARCITY /
  QOS_SCARCITY — never conflated (Eq. 12).
* **Idempotent rollback**: release on both planes tolerates repeats, so a
  crashed coordinator can always be re-driven to a clean state.
* **Orphan reaping**: every PREPARE is tracked until its COMMIT/ABORT
  arrives; :meth:`TwoPhaseCoordinator.reap` aborts the ones whose decision
  was lost in flight once τ_prep + τ_com + hold has passed — the timers
  are enforced, not advisory.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.catalog import ModelEntry
from repro.core.clock import Clock
from repro.core.failures import FailureCause, SessionError, Timers
from repro.core.qos import QoSFlowManager, TransportClass
from repro.core.session import Binding


@dataclass
class Prepared:
    """Result of a successful PREPARE: both provisional leases."""
    compute_lease_id: str
    qos_lease_id: str
    site_id: str
    qfi: int
    prepared_at: float
    #: extra seconds the provisional leases stay committable beyond τ_com —
    #: make-before-break migration holds the target through the τ_mig
    #: transfer window while the source keeps serving
    hold_s: float = 0.0


class TwoPhaseCoordinator:
    def __init__(self, clock: Clock, sites, qos: QoSFlowManager,
                 timers: Timers):
        self.clock = clock
        self.sites = sites
        self.qos = qos
        self.timers = timers
        self.log: list = []    # coordinator write-ahead log (audit + tests)
        #: PREPAREs whose COMMIT/ABORT has not arrived, by compute lease id
        #: — the reaper's work queue when a decision is lost in flight
        self.outstanding: Dict[str, Prepared] = {}

    def _deadline_guard(self, t0: float, tau: float, phase: str) -> None:
        if self.clock.now() - t0 > tau:
            raise SessionError(FailureCause.DEADLINE_EXPIRY,
                               f"{phase} exceeded τ={tau}s")

    # ------------------------------------------------------------------
    def prepare(self, model: ModelEntry, site_id: str, zone: str,
                klass: TransportClass, *, slots: int,
                cache_bytes: float, hold_s: float = 0.0) -> Prepared:
        """Stage 1: obtain BOTH provisional leases or none. ``hold_s``
        extends the provisional TTL and the COMMIT window (migration holds
        the target across the τ_mig state-transfer window)."""
        t0 = self.clock.now()
        site = self.sites[site_id]
        ttl_s = self.timers.tau_prep + self.timers.tau_com + hold_s
        self.log.append(("prepare.begin", t0, site_id))
        cmp_lease = site.prepare(model, slots=slots, cache_bytes=cache_bytes,
                                 ttl_s=ttl_s)
        try:
            self._deadline_guard(t0, self.timers.tau_prep, "PREPARE(compute)")
            qos_lease = self.qos.prepare(
                (zone, site_id), klass, ttl_s=ttl_s)
        except BaseException:
            # roll back the compute side before surfacing the QoS failure —
            # partial allocation must never escape this function
            site.release(cmp_lease.lease_id)
            self.log.append(("prepare.rollback", self.clock.now(), site_id))
            raise
        try:
            self._deadline_guard(t0, self.timers.tau_prep, "PREPARE")
        except BaseException:
            site.release(cmp_lease.lease_id)
            self.qos.release(qos_lease.lease_id)
            self.log.append(("prepare.rollback", self.clock.now(), site_id))
            raise
        self.log.append(("prepare.ok", self.clock.now(), site_id))
        prepared = Prepared(compute_lease_id=cmp_lease.lease_id,
                            qos_lease_id=qos_lease.lease_id,
                            site_id=site_id, qfi=qos_lease.qfi,
                            prepared_at=self.clock.now(), hold_s=hold_s)
        self.outstanding[prepared.compute_lease_id] = prepared
        return prepared

    # ------------------------------------------------------------------
    def prepare_transport(self, path, klass: TransportClass, *,
                          ttl_s: float):
        """Home-side half of a CROSS-DOMAIN prepare: only the transport
        plane is reserved locally (the access + inter-domain leg) — the
        compute half is the visited domain's own coordinator, driven over
        the east-west wire. Logged in the same WAL so a federated 2PC is
        auditable end to end; returns the provisional QoS lease."""
        t0 = self.clock.now()
        self.log.append(("prepare_transport.begin", t0, path))
        lease = self.qos.prepare(path, klass, ttl_s=ttl_s)
        self.log.append(("prepare_transport.ok", self.clock.now(), path))
        return lease

    # ------------------------------------------------------------------
    def commit(self, prepared: Prepared, model: ModelEntry) -> Binding:
        """Stage 2: confirm both leases; on ANY failure release both."""
        t0 = self.clock.now()
        site = self.sites[prepared.site_id]
        self.outstanding.pop(prepared.compute_lease_id, None)
        try:
            self._deadline_guard(prepared.prepared_at,
                                 self.timers.tau_com + prepared.hold_s,
                                 "COMMIT")
            site.confirm(prepared.compute_lease_id,
                         lease_s=self.timers.lease_s)
            self.qos.confirm(prepared.qos_lease_id,
                             lease_s=self.timers.lease_s)
        except BaseException:
            self.abort(prepared)
            raise
        self.log.append(("commit.ok", self.clock.now(), prepared.site_id))
        return Binding(
            model_id=model.model_id, model_version=model.version,
            site_id=prepared.site_id,
            endpoint=f"aiaas://{prepared.site_id}/{model.model_id}",
            qfi=prepared.qfi,
            steering_handle=f"steer/{prepared.site_id}/qfi{prepared.qfi}",
            compute_lease_id=prepared.compute_lease_id,
            qos_lease_id=prepared.qos_lease_id)

    # ------------------------------------------------------------------
    def abort(self, prepared: Prepared) -> None:
        """Idempotent rollback of both provisional leases."""
        self.outstanding.pop(prepared.compute_lease_id, None)
        self.sites[prepared.site_id].release(prepared.compute_lease_id)
        self.qos.release(prepared.qos_lease_id)
        self.log.append(("abort", self.clock.now(), prepared.site_id))

    # ------------------------------------------------------------------
    def reap(self, now: Optional[float] = None) -> List[Prepared]:
        """Abort every outstanding PREPARE whose decision window has
        passed (τ_prep + τ_com + hold) — the COMMIT/ABORT was lost in
        flight and no caller will ever re-drive it. Idempotent; called on
        the plane-heartbeat cadence."""
        now = self.clock.now() if now is None else now
        horizon = self.timers.tau_prep + self.timers.tau_com
        orphans = [p for p in self.outstanding.values()
                   if now - p.prepared_at > horizon + p.hold_s]
        for p in orphans:
            self.log.append(("reap", now, p.site_id))
            self.abort(p)
        return orphans
