"""Feasibility predictors: T̂ff(m,e,ξ), L̂99(m,e,ξ), Γ̂(m,e) — Eq. (7)–(9).

The paper deliberately leaves predictor internals as "competitive space";
this implementation ties them to the systems substrate:

* **Execution side** — service time from the roofline model of the target
  hardware (FLOPs/token vs peak FLOP/s, bytes/token vs HBM bandwidth; the
  same constants as EXPERIMENTS.md §Roofline), queue wait from an M/M/c
  approximation driven by the analytics ξ (measured utilization), and a
  lognormal execution-tail assumption calibrated by measured p99 when
  boundary telemetry exists.
* **Transport side** — per-QoS-class latency classes (repro.core.qos).

Every predicted quantity is in the same units as the ASP objectives, so
anchoring risk (Eq. 9) and migration triggers (Eq. 14) are falsifiable
against Z(t) (Eq. 13).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.analytics import Analytics, SiteContext
from repro.core.asp import ASP
from repro.core.catalog import ModelEntry
from repro.core.qos import TransportClass

#: lognormal sigma for execution-time variability (calibrated vs §V sim)
_EXEC_SIGMA = 0.35
#: z-scores
_Z95, _Z99 = 1.645, 2.326


def _lognormal_quantile(median: float, sigma: float, z: float) -> float:
    return median * math.exp(sigma * z)


@dataclass
class Prediction:
    t_ff_ms: float          # T̂ff
    l99_ms: float           # L̂99
    l95_ms: float
    cost_per_1k: float      # Γ̂ (per 1k tokens)
    decode_ms_per_token: float
    queue_wait_ms: float
    p_violate_l99: float
    p_violate_ttfb: float
    p_migration: float


class Predictors:
    #: memo bound: cleared wholesale past this size (establish storms over
    #: many ASPs; the epoch key already retires stale entries naturally)
    _MEMO_MAX = 65_536

    def __init__(self, analytics: Analytics, *, mfu: float = 0.4,
                 bw_eff: float = 0.6):
        self.analytics = analytics
        self.mfu = mfu          # achievable fraction of peak FLOP/s
        self.bw_eff = bw_eff    # achievable fraction of HBM bandwidth
        # memoized predictions keyed on (ASP digest, model, site, zone,
        # class, request shape, site load-epoch): DISCOVER evaluates the
        # full model×site cross product on EVERY establish, and federated
        # discovery multiplies that by the number of solicited domains —
        # identical ξ must not recompute the roofline/queue math
        self._memo: dict = {}
        self.memo_hits = 0
        self.memo_misses = 0

    # -- execution-side service times ------------------------------------
    def prefill_ms(self, model: ModelEntry, site, prompt_tokens: int) -> float:
        flops = model.prefill_flops_per_token() * prompt_tokens
        return 1e3 * flops / (site.spec.peak_flops * self.mfu)

    def decode_ms_per_token(self, model: ModelEntry, site, context: int) -> float:
        """Decode is memory-bound: per-token bytes / effective bandwidth."""
        byts = model.decode_bytes_per_token(context)
        t_mem = byts / (site.spec.hbm_bw * self.bw_eff)
        t_cmp = model.decode_flops_per_token() / (site.spec.peak_flops * self.mfu)
        return 1e3 * max(t_mem, t_cmp)

    def queue_wait_ms(self, site, ctx: SiteContext, service_ms: float) -> float:
        """M/M/c wait with c = free decode slots; driven by measured ξ."""
        rho = min(ctx.utilization, 0.999)
        c = max(site.spec.decode_slots, 1)
        # Sakasegawa approximation: Wq ≈ (ρ^(√(2(c+1)))/ (c(1-ρ))) · service
        wq = (rho ** math.sqrt(2 * (c + 1))) / (c * (1 - rho)) * service_ms
        wq *= c  # scale back to per-request units
        # measured backlog (serving-plane queue depth, per slot): each queued
        # request ahead contributes ~one service time per slot — this is the
        # term that makes Eq. (14) triggers fire under real congestion
        wq += ctx.queue_depth * service_ms
        # KV page-pool pressure (paged engines): near-full pools force
        # hibernate/resume churn on admission, so expected wait grows
        # sharply as page_util -> 1; exactly zero when unreported (0.0)
        if ctx.page_util > 0.0:
            wq += (ctx.page_util ** 4) / max(1.0 - ctx.page_util, 1.0 / 16.0) \
                * service_ms
        return wq

    # -- headline predictions ------------------------------------------------
    def predict(self, asp: ASP, model: ModelEntry, site, zone: str,
                klass: TransportClass, *, prompt_tokens: int = 512,
                gen_tokens: int = 256) -> Prediction:
        # memo hit ⟺ same contract, placement, shape AND unchanged ξ —
        # every heartbeat observation bumps the site's load epoch, so
        # cached predictions can never outlive the evidence behind them
        key = (asp.digest(), f"{model.model_id}@{model.version}",
               site.spec.site_id, zone, klass.name,
               prompt_tokens, gen_tokens,
               self.analytics.load_epoch(site.spec.site_id))
        cached = self._memo.get(key)
        if cached is not None:
            self.memo_hits += 1
            return cached
        self.memo_misses += 1
        pred = self._predict(asp, model, site, zone, klass,
                             prompt_tokens=prompt_tokens,
                             gen_tokens=gen_tokens)
        if len(self._memo) >= self._MEMO_MAX:
            self._memo.clear()
        self._memo[key] = pred
        return pred

    def _predict(self, asp: ASP, model: ModelEntry, site, zone: str,
                 klass: TransportClass, *, prompt_tokens: int = 512,
                 gen_tokens: int = 256) -> Prediction:
        rtt = site.spec.rtt_ms.get(zone, 60.0)
        transport_ms = rtt + klass.base_ms
        transport_p99 = rtt + min(
            klass.p999_cap_ms,
            klass.base_ms + _Z99 * klass.jitter_ms * 3)

        ctx = self.analytics.site_context(site.spec.site_id)
        prefill = self.prefill_ms(model, site, prompt_tokens)
        dms = self.decode_ms_per_token(model, site, prompt_tokens + gen_tokens)
        wq = self.queue_wait_ms(site, ctx, prefill + gen_tokens * dms)

        t_ff_med = transport_ms + wq + prefill
        # completion latency: full generation
        l_med = transport_ms + wq + prefill + gen_tokens * dms
        measured = self.analytics.measured_p99(
            site.spec.site_id, f"{model.model_id}@{model.version}")
        l99 = _lognormal_quantile(l_med, _EXEC_SIGMA, _Z99) + transport_p99 - transport_ms
        if measured is not None:  # calibrate on boundary evidence
            l99 = 0.5 * l99 + 0.5 * measured
        l95 = _lognormal_quantile(l_med, _EXEC_SIGMA, _Z95)

        # violation probabilities under the lognormal tail
        def p_exceed(bound_ms: float, med: float) -> float:
            if med <= 0:
                return 0.0
            z = math.log(max(bound_ms, 1e-9) / med) / _EXEC_SIGMA
            return 0.5 * math.erfc(z / math.sqrt(2))

        p_l99 = p_exceed(asp.objectives.p99_ms, l_med)
        p_ttfb = p_exceed(asp.objectives.ttfb_ms, t_ff_med)

        # migration likelihood over the session horizon: mobility-driven RTT
        # drift away from edge sites — central sites rarely need re-anchoring
        ho_rate = 0.0
        if asp.continuity_required():
            # defaulted: unknown site kinds (new deployments, federated
            # guests) predict like a regional anchor instead of 500-ing
            # DISCOVER with a KeyError
            base = {"edge": 0.8, "regional": 0.3,
                    "central": 0.05}.get(site.spec.kind, 0.3)
            ho_rate = base
        p_mig = 1.0 - math.exp(-ho_rate)

        # cost: chip-seconds per 1k tokens × price + model license price
        chip_s_per_1k = (1000 * dms / 1e3) * site.spec.chips * \
            (1.0 / max(site.spec.decode_slots, 1))
        cost = model.price_per_1k_tokens + chip_s_per_1k * site.spec.price_per_chip_s * 1e3
        return Prediction(
            t_ff_ms=t_ff_med, l99_ms=l99, l95_ms=l95, cost_per_1k=cost,
            decode_ms_per_token=dms, queue_wait_ms=wq,
            p_violate_l99=p_l99, p_violate_ttfb=p_ttfb, p_migration=p_mig)
