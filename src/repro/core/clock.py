"""Injectable clock: the whole control plane is written against this so the
lifecycle (leases, deadlines Eq. 11, make-before-break) is deterministic in
tests and in the §V Monte-Carlo simulation."""

from __future__ import annotations

import time


class Clock:
    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        time.sleep(dt)


class VirtualClock(Clock):
    """Deterministic clock for tests/simulation."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def sleep(self, dt: float) -> None:
        self.advance(dt)

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("time moves forward")
        self._t += dt
        return self._t
