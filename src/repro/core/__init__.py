"""NE-AIaaS core: the paper's contract layer (ASP, AIS, lifecycle procedures)."""

from repro.core.asp import ASP, Objectives, Modality, InteractionMode, \
    MobilityClass, QualityTier, default_asp  # noqa: F401
from repro.core.failures import FailureCause, SessionError, Timers, REMEDIATION  # noqa: F401
from repro.core.session import AISession, SessionState, Binding  # noqa: F401
from repro.core.orchestrator import Orchestrator, ServeResult  # noqa: F401
