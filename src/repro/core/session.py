"""AI Session (AIS) — the paper's lifecycle object (Section III-B).

The binding record stores exactly the identifiers Section III-B lists:
session id, ASP digest, model/version, anchor site, routable endpoint,
QoS-flow handle (QFI) + steering handle, validity lease, authorization/
consent reference, charging reference.

State machine::

    IDLE → DISCOVERED → ANCHORED → PREPARING → PREPARED → COMMITTED
                                                          ↕ (serving)
                                                       MIGRATING
    any → FAILED(cause) / RELEASED

Invariants enforced *by construction*:

* Eq. (4)/(10): ``committed(t) ⟺ v_cmp(t) ∧ v_qos(t)`` — the only path into
  COMMITTED is ``bind()`` which requires both confirmed leases; ``committed``
  re-evaluates lease validity at call time, so an expired lease on either
  side immediately removes the session from the committed domain. Partial
  allocation is not representable: there is no API that stores a single
  confirmed lease on a session.
* Eq. (6): ``¬v_σ(t) ⟹ ServeDisabled(t⁺)`` — ``serve_allowed`` checks the
  consent reference's validity on every call.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional

from repro.core.asp import ASP
from repro.core.clock import Clock
from repro.core.failures import FailureCause, SessionError


class SessionState(enum.Enum):
    IDLE = "idle"
    DISCOVERED = "discovered"
    ANCHORED = "anchored"
    PREPARING = "preparing"
    PREPARED = "prepared"
    COMMITTED = "committed"
    MIGRATING = "migrating"
    RELEASED = "released"
    FAILED = "failed"


_LEGAL = {
    SessionState.IDLE: {SessionState.DISCOVERED, SessionState.FAILED},
    SessionState.DISCOVERED: {SessionState.ANCHORED, SessionState.FAILED},
    SessionState.ANCHORED: {SessionState.PREPARING, SessionState.FAILED},
    SessionState.PREPARING: {SessionState.PREPARED, SessionState.FAILED},
    SessionState.PREPARED: {SessionState.COMMITTED, SessionState.FAILED},
    SessionState.COMMITTED: {SessionState.MIGRATING, SessionState.RELEASED,
                             SessionState.FAILED},
    SessionState.MIGRATING: {SessionState.COMMITTED, SessionState.RELEASED,
                             SessionState.FAILED},
    SessionState.RELEASED: set(),
    SessionState.FAILED: set(),
}


@dataclass
class Binding:
    """One committed (model, anchor, transport) binding with its leases."""
    model_id: str
    model_version: str
    site_id: str
    endpoint: str               # routable service endpoint at the site
    qfi: int
    steering_handle: str
    compute_lease_id: str
    qos_lease_id: str


_ids = itertools.count(1)


class AISession:
    def __init__(self, asp: ASP, invoker: str, zone: str, clock: Clock,
                 *, sites, qos, policy):
        asp.validate()
        self.session_id = f"ais-{next(_ids):06d}"
        self.asp = asp
        self.asp_digest = asp.digest()
        self.invoker = invoker
        self.zone = zone
        self.clock = clock
        self._sites = sites          # site registry (site_id -> ExecutionSite)
        self._qos = qos              # QoSFlowManager
        self._policy = policy        # consent/charging (v_σ)
        self.state = SessionState.IDLE
        self.binding: Optional[Binding] = None
        self.failure: Optional[FailureCause] = None
        self.authz_ref: Optional[str] = None
        self.charging_ref: Optional[str] = None
        self.history: list = []      # (t, state) audit trail
        #: served context length (prompt + generated tokens across requests);
        #: sizes the migration payload and PREPARE cache reservation
        self.context_tokens: int = 0
        #: absolute (clock.now()-domain) establishment deadline, set when a
        #: request carried a shrinking ``deadline_ms`` budget; None = no
        #: enforcement. Later hops reject work they cannot finish by this.
        self.deadline_at: Optional[float] = None

    # ------------------------------------------------------------------
    # state machine
    # ------------------------------------------------------------------
    def _to(self, new: SessionState) -> None:
        if new not in _LEGAL[self.state]:
            raise SessionError(
                FailureCause.POLICY_DENIAL,
                f"illegal transition {self.state.value} → {new.value}")
        self.state = new
        self.history.append((self.clock.now(), new.value))

    def mark_discovered(self):
        self._to(SessionState.DISCOVERED)

    def mark_anchored(self):
        self._to(SessionState.ANCHORED)

    def mark_preparing(self):
        self._to(SessionState.PREPARING)

    def mark_prepared(self):
        self._to(SessionState.PREPARED)

    def mark_migrating(self):
        self._to(SessionState.MIGRATING)

    def note_context(self, tokens: int) -> None:
        """Account served tokens (prompt + generated) into the session's
        context length — the actual migration payload size (not a constant)."""
        self.context_tokens += max(int(tokens), 0)

    def fail(self, cause: FailureCause, detail: str = "") -> None:
        # release any leases this session still references (idempotent)
        if self.binding:
            self._release_binding(self.binding)
            self.binding = None
        self.failure = cause
        self.state = SessionState.FAILED
        self.history.append((self.clock.now(), f"failed:{cause.value}"))

    # ------------------------------------------------------------------
    # commitment coupling — Eq. (4)/(10)
    # ------------------------------------------------------------------
    def bind(self, binding: Binding) -> None:
        """The ONLY path into COMMITTED. Requires both leases confirmed and
        currently valid — checked against the resource planes, not cached."""
        site = self._sites[binding.site_id]
        if not site.lease_valid(binding.compute_lease_id):
            raise SessionError(FailureCause.DEADLINE_EXPIRY,
                               "compute lease invalid at bind()")
        if not self._qos.lease_valid(binding.qos_lease_id):
            raise SessionError(FailureCause.DEADLINE_EXPIRY,
                               "QoS lease invalid at bind()")
        old = self.binding
        self.binding = binding
        if self.state == SessionState.MIGRATING:
            # make-before-break: release the OLD binding only after the new
            # one is committed (continuity without contract gaps)
            self._to(SessionState.COMMITTED)
            if old is not None:
                self._release_binding(old)
        else:
            self._to(SessionState.COMMITTED)

    def v_cmp(self, now: Optional[float] = None) -> bool:
        if self.binding is None:
            return False
        return self._sites[self.binding.site_id].lease_valid(
            self.binding.compute_lease_id)

    def v_qos(self, now: Optional[float] = None) -> bool:
        if self.binding is None:
            return False
        return self._qos.lease_valid(self.binding.qos_lease_id)

    def v_sigma(self) -> bool:
        """Authorization/consent scope validity (Eq. 6)."""
        return self._policy.consent_valid(self.authz_ref)

    def committed(self) -> bool:
        """Eq. (4)/(10): Committed(t) ⟺ v_cmp(t) ∧ v_qos(t)."""
        return (self.state in (SessionState.COMMITTED, SessionState.MIGRATING)
                and self.v_cmp() and self.v_qos())

    def serve_allowed(self) -> bool:
        """Eq. (6): revocation disables service regardless of resources."""
        return self.committed() and self.v_sigma()

    def renew(self, lease_s: float) -> bool:
        """Heartbeat: extend both leases atomically (both or neither)."""
        if self.binding is None:
            return False
        site = self._sites[self.binding.site_id]
        if not (site.lease_valid(self.binding.compute_lease_id)
                and self._qos.lease_valid(self.binding.qos_lease_id)):
            return False
        ok1 = site.renew(self.binding.compute_lease_id, lease_s)
        ok2 = self._qos.renew(self.binding.qos_lease_id, lease_s)
        return ok1 and ok2

    # ------------------------------------------------------------------
    # teardown
    # ------------------------------------------------------------------
    def _release_binding(self, b: Binding) -> None:
        self._sites[b.site_id].release(b.compute_lease_id)
        self._qos.release(b.qos_lease_id)

    def release(self) -> None:
        if self.binding:
            self._release_binding(self.binding)
            self.binding = None
        self._to(SessionState.RELEASED)

    # ------------------------------------------------------------------
    def record(self) -> dict:
        """The auditable binding record (Section III-B)."""
        b = self.binding
        return {
            "session_id": self.session_id,
            "asp_digest": self.asp_digest,
            "state": self.state.value,
            "model": f"{b.model_id}@{b.model_version}" if b else None,
            "anchor": b.site_id if b else None,
            "endpoint": b.endpoint if b else None,
            "qfi": b.qfi if b else None,
            "steering": b.steering_handle if b else None,
            "authz_ref": self.authz_ref,
            "charging_ref": self.charging_ref,
            "failure": self.failure.value if self.failure else None,
        }
