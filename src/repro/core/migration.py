"""Make-before-break MIGRATION (Section IV-B, Eq. 14).

Protocol (continuity invariant: the session never leaves the domain where
Committed(t) holds):

  1. trigger  — predicted violation risk (Eq. 14) or measured non-compliance
  2. re-DISCOVER + re-PAGE excluding the current anchor
  3. PREPARE on the target while the current binding stays committed
  4. transfer session state (KV cache / recurrent state) within τ_mig
  5. COMMIT target  →  bind() swaps bindings atomically  →  release source

Aborts at any step preserve the existing committed service: the target's
provisional leases are rolled back and the source binding is untouched
(STATE_TRANSFER_FAILURE / DEADLINE_EXPIRY are diagnosable causes, not
session teardown).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.asp import ASP
from repro.core.clock import Clock
from repro.core.discovery import discover
from repro.core.failures import FailureCause, SessionError, Timers
from repro.core.paging import page
from repro.core.session import AISession
from repro.core.twophase import TwoPhaseCoordinator


@dataclass
class MigrationOutcome:
    migrated: bool
    aborted: bool
    cause: Optional[FailureCause]
    from_site: str
    to_site: Optional[str]
    interruption_ms: float       # contract-gap time (0 for successful MBB)
    transfer_ms: float = 0.0


@dataclass
class MigrationTriggers:
    """Eq. (14) thresholds δ, δ'."""
    delta_l99: float = 0.35
    delta_ttfb: float = 0.35

    def should_migrate(self, p_l99: float, p_ttfb: float) -> bool:
        return p_l99 >= self.delta_l99 or p_ttfb >= self.delta_ttfb


class MigrationController:
    def __init__(self, clock: Clock, coordinator: TwoPhaseCoordinator,
                 catalog, sites, predictors, timers: Timers,
                 *, transfer_fn: Optional[Callable] = None,
                 analytics=None):
        """``transfer_fn(session, from_site, to_site) -> transfer_seconds``
        moves the session state; default models the wire time of the cache
        payload over the inter-site link (5 GB/s DCN per DESIGN.md)."""
        self.clock = clock
        self.coord = coordinator
        self.catalog = catalog
        self.sites = sites
        self.predictors = predictors
        self.timers = timers
        self.transfer_fn = transfer_fn or self._default_transfer
        self.analytics = analytics

    # ------------------------------------------------------------------
    def _default_transfer(self, session: AISession, from_site, to_site,
                          *, context_tokens: int = 2048) -> float:
        model = self.catalog.get(session.binding.model_id,
                                 session.binding.model_version)
        payload = model.session_state_bytes(context_tokens)
        dcn_bw = 5e9  # inter-site link, bytes/s
        return payload / dcn_bw

    # ------------------------------------------------------------------
    def check_trigger(self, session: AISession, zone: str,
                      triggers: MigrationTriggers) -> bool:
        """Eq. (14) evaluated against the *current* anchor."""
        if not session.committed():
            return False
        b = session.binding
        model = self.catalog.get(b.model_id, b.model_version)
        site = self.sites[b.site_id]
        from repro.core.qos import PREMIUM, BEST_EFFORT
        klass = PREMIUM if session.asp.tier >= 2 else BEST_EFFORT
        pred = self.predictors.predict(session.asp, model, site, zone, klass)
        return triggers.should_migrate(pred.p_violate_l99,
                                       pred.p_violate_ttfb)

    # ------------------------------------------------------------------
    def migrate(self, session: AISession, zone: str) -> MigrationOutcome:
        if not session.committed():
            raise SessionError(FailureCause.POLICY_DENIAL,
                               "migration requires a committed session")
        src = session.binding.site_id
        t0 = self.clock.now()
        session.mark_migrating()
        prepared = None
        try:
            cands = discover(session.asp, self.catalog, self.sites,
                             self.predictors, zone, analytics=self.analytics)
            target = page(session.asp, cands, exclude_sites=(src,))
            model = target.model
            prepared = self.coord.prepare(
                model, target.site_id, zone, target.klass, slots=1,
                cache_bytes=model.session_state_bytes(2048))
            # ---- state transfer under τ_mig, source still committed -----
            transfer_s = self.transfer_fn(session, self.sites[src],
                                          self.sites[target.site_id])
            if transfer_s > self.timers.tau_mig:
                raise SessionError(
                    FailureCause.STATE_TRANSFER_FAILURE,
                    f"transfer {transfer_s:.3f}s exceeds τ_mig="
                    f"{self.timers.tau_mig}s")
            self.clock.sleep(transfer_s)
            if self.clock.now() - t0 > self.timers.tau_mig:
                raise SessionError(FailureCause.DEADLINE_EXPIRY,
                                   "migration deadline expired")
            # ---- commit target, THEN the old binding is released ---------
            binding = self.coord.commit(prepared, model)
            session.bind(binding)   # make-before-break swap (session.bind)
            return MigrationOutcome(
                migrated=True, aborted=False, cause=None, from_site=src,
                to_site=target.site_id, interruption_ms=0.0,
                transfer_ms=transfer_s * 1e3)
        except SessionError as e:
            # abort: roll back the target, keep serving on the source
            if prepared is not None:
                self.coord.abort(prepared)
            if session.state.value == "migrating":
                # still committed on the source ⇒ fall back without teardown
                session.state = type(session.state).COMMITTED
                session.history.append((self.clock.now(),
                                        f"migration-aborted:{e.cause.value}"))
            return MigrationOutcome(
                migrated=False, aborted=True, cause=e.cause, from_site=src,
                to_site=None, interruption_ms=0.0)
