"""Make-before-break MIGRATION (Section IV-B, Eq. 14).

Protocol (continuity invariant: the session never leaves the domain where
Committed(t) holds):

  1. trigger  — predicted violation risk (Eq. 14) or measured non-compliance
  2. re-DISCOVER + re-PAGE excluding the current anchor
  3. PREPARE on the target while the current binding stays committed —
     the source keeps decoding (tokens flow) through this whole window
  4. transfer session state (KV cache / recurrent state) within τ_mig:
     the data plane exports the source slot between decode steps, installs
     it into the target backend, and verifies the fingerprint
  5. COMMIT target  →  bind() swaps bindings atomically  →  release source
     slot and leases; an in-flight stream resumes on the TARGET plane

Aborts at any step preserve the existing committed service: the target's
provisional leases AND any provisionally imported state are rolled back,
the source slot is untouched, and a detached in-flight stream is
re-attached to the source plane (STATE_TRANSFER_FAILURE / DEADLINE_EXPIRY /
COMPUTE_SCARCITY are diagnosable causes, not session teardown).

The data plane is pluggable through ``transfer_fn``:

* a plain callable ``(session, from_site, to_site) -> seconds`` models wire
  time only (closed-form; the §V mobility baseline injects failures here);
* an object with ``begin/commit/abort`` — :class:`PlaneTransferPath` — moves
  REAL state through the sites' ServingPlanes via
  :mod:`repro.serving.state_transfer`, with two-phase ordering aligned to
  the control plane's PREPARE/COMMIT.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.asp import ASP
from repro.core.clock import Clock
from repro.core.discovery import discover
from repro.core.failures import FailureCause, SessionError, Timers
from repro.core.paging import page
from repro.core.session import AISession
from repro.core.twophase import TwoPhaseCoordinator


@dataclass
class MigrationOutcome:
    migrated: bool
    aborted: bool
    cause: Optional[FailureCause]
    from_site: str
    to_site: Optional[str]
    interruption_ms: float       # contract-gap time (0 for successful MBB)
    transfer_ms: float = 0.0
    transfer_bytes: int = 0      # actual payload moved by the data plane
    fingerprint: Optional[str] = None   # verified state fingerprint
    mid_stream: bool = False     # an in-flight request followed the session


@dataclass
class MigrationTriggers:
    """Eq. (14) thresholds δ, δ'."""
    delta_l99: float = 0.35
    delta_ttfb: float = 0.35

    def should_migrate(self, p_l99: float, p_ttfb: float) -> bool:
        return p_l99 >= self.delta_l99 or p_ttfb >= self.delta_ttfb


@dataclass
class TransferTicket:
    """Provisional state of one data-plane transfer (begin → commit/abort)."""
    session_id: str
    src_plane: object
    dst_plane: object
    handoff: object = None       # SessionHandoff (in-flight stream), if any
    moved_state: bool = False    # destination holds a provisional import
    wire_s: float = 0.0
    nbytes: int = 0
    fingerprint: Optional[str] = None


class PlaneTransferPath:
    """Two-phase migration data plane over the per-site ServingPlanes.

    ``begin`` exports the session's slot from the source plane's backend,
    installs it into the target's (fingerprint-verified), and detaches any
    in-flight request — the source slot itself stays allocated, so an abort
    is a pure rollback. ``commit`` releases the source slot and re-attaches
    the stream on the target (the break of make-before-break); ``abort``
    rolls the provisional import back and resumes streaming on the source.

    Failure injection is read from each plane's ``migration_inject``
    (:class:`repro.serving.state_transfer.TransferInjections`): export-side
    hooks from the SOURCE plane, import-side hooks from the TARGET plane.
    """

    def __init__(self, plane_for: Callable[[object], object], *,
                 link_bw: float = 5e9, verify: bool = True,
                 overlap_rounds: int = 1, clock: Optional[Clock] = None,
                 ew_link_bw: float = 1.25e9):
        self.plane_for = plane_for
        self.link_bw = link_bw
        self.verify = verify
        #: source decode rounds run inside ``begin`` before the swap point —
        #: the source literally keeps producing tokens while the target
        #: prepares (set 0 to disable for pure control-plane callers)
        self.overlap_rounds = overlap_rounds
        self.clock = clock
        #: inter-domain (east-west) link: roaming state crosses operator
        #: boundaries over a peering link, not the intra-domain DCN
        self.ew_link_bw = ew_link_bw

    @staticmethod
    def _boundary_scrub(payload: dict) -> dict:
        """Exposure boundary for roaming transfers: only the slot-essential
        state (cache tensors, position, last token) crosses the domain
        boundary — any auxiliary per-request bookkeeping a backend attaches
        stays home (§ federation trust boundary)."""
        keep = ("cache", "position", "last_token", "adapter_id")
        return {k: v for k, v in payload.items() if k in keep}

    # ------------------------------------------------------------------
    def _injections(self, src_plane, dst_plane):
        from repro.serving.state_transfer import TransferInjections
        src = getattr(src_plane, "migration_inject", None)
        dst = getattr(dst_plane, "migration_inject", None)
        if src is None and dst is None:
            return None
        return TransferInjections(
            on_export=src.on_export if src else None,
            corrupt=src.corrupt if src else None,
            on_import=dst.on_import if dst else None,
            deny_admission=dst.deny_admission if dst else False,
            extra_wire_s=(src.extra_wire_s if src else 0.0)
            + (dst.extra_wire_s if dst else 0.0))

    # ------------------------------------------------------------------
    def begin(self, session: AISession, src_site, dst_site, *,
              payload_bytes: Optional[int] = None) -> TransferTicket:
        from repro.serving import state_transfer
        src_plane = self.plane_for(src_site)
        dst_plane = self.plane_for(dst_site)
        sid = session.session_id
        backend = src_plane.backend
        cross_domain = getattr(src_site, "domain_id", None) != \
            getattr(dst_site, "domain_id", None)
        link_bw = self.ew_link_bw if cross_domain else self.link_bw
        # source keeps streaming while the target prepares: run decode
        # rounds up to the swap point (tokens produced here are accounted
        # to the source plane's in-flight request as usual)
        for _ in range(self.overlap_rounds):
            if not src_plane._round():
                break
        if not (hasattr(backend, "has_slot") and backend.has_slot(sid)):
            # no data-plane state yet: nothing to export, but any queued
            # requests still follow the session to its new anchor; model
            # the wire time of the declared payload
            handoff = src_plane.detach_session(sid)
            wire = (payload_bytes or 0) / link_bw
            inj = self._injections(src_plane, dst_plane)
            if inj is not None:
                wire += inj.extra_wire_s
            return TransferTicket(sid, src_plane, dst_plane, handoff=handoff,
                                  wire_s=wire, nbytes=int(payload_bytes or 0))
        handoff = src_plane.detach_session(sid)
        try:
            meta = state_transfer.transfer(
                backend, dst_plane.backend, sid,
                link_bw=link_bw, verify=self.verify,
                inject=self._injections(src_plane, dst_plane),
                scrub=self._boundary_scrub if cross_domain else None,
                clock=self.clock)
        except SessionError:
            src_plane.attach_session(handoff)
            raise
        except state_transfer.AdmissionDenied as e:
            # resume streaming on the source; admission denial maps to
            # COMPUTE_SCARCITY in the Eq. (12) cause partition
            src_plane.attach_session(handoff)
            raise SessionError(FailureCause.COMPUTE_SCARCITY, str(e))
        except Exception as e:
            src_plane.attach_session(handoff)
            raise SessionError(FailureCause.STATE_TRANSFER_FAILURE, str(e))
        wire_bytes = max(meta["bytes"], int(payload_bytes or 0))
        extra = meta["wire_s_at_link"] - meta["bytes"] / link_bw
        return TransferTicket(
            sid, src_plane, dst_plane, handoff=handoff, moved_state=True,
            wire_s=wire_bytes / link_bw + extra,
            nbytes=meta["bytes"], fingerprint=meta["fingerprint"])

    def commit(self, ticket: TransferTicket) -> None:
        """The break: source slot released only after the target committed;
        the detached in-flight stream and queued requests resume on the
        target plane."""
        if ticket.moved_state:
            ticket.src_plane.backend.release_slot(ticket.session_id)
        if ticket.handoff is not None and not ticket.handoff.empty():
            ticket.dst_plane.attach_session(ticket.handoff)

    def abort(self, ticket: TransferTicket) -> None:
        """Rollback: drop the provisional import, resume on the source."""
        if ticket.moved_state:
            ticket.dst_plane.backend.release_slot(ticket.session_id)
        if ticket.handoff is not None and not ticket.handoff.empty():
            ticket.src_plane.attach_session(ticket.handoff)


class MigrationController:
    def __init__(self, clock: Clock, coordinator: TwoPhaseCoordinator,
                 catalog, sites, predictors, timers: Timers,
                 *, transfer_fn: Optional[Callable] = None,
                 analytics=None):
        """``transfer_fn`` is either a plain callable
        ``(session, from_site, to_site) -> transfer_seconds`` (closed-form
        wire model), or a two-phase :class:`PlaneTransferPath`-style object
        with ``begin/commit/abort`` that moves real state. The default
        models the wire time of the cache payload over the inter-site link
        (5 GB/s DCN per DESIGN.md)."""
        self.clock = clock
        self.coord = coordinator
        self.catalog = catalog
        self.sites = sites
        self.predictors = predictors
        self.timers = timers
        self.transfer_fn = transfer_fn or self._default_transfer
        self.analytics = analytics
        #: set by a federation DomainController: re-paging then considers
        #: east-west offers, and a remote target drives the cross-domain
        #: 2PC — roaming make-before-break through the same transfer path
        self.federation = None

    # ------------------------------------------------------------------
    def context_tokens(self, session: AISession) -> int:
        """The session's ACTUAL context length (prompt + generated tokens
        served so far) — sizes the PREPARE cache reservation and the
        transfer payload. Floor of 1 keeps never-served sessions movable."""
        return max(int(getattr(session, "context_tokens", 0)), 1)

    def _default_transfer(self, session: AISession, from_site, to_site,
                          *, context_tokens: Optional[int] = None) -> float:
        model = self.catalog.get(session.binding.model_id,
                                 session.binding.model_version)
        ctx = context_tokens if context_tokens is not None \
            else self.context_tokens(session)
        payload = model.session_state_bytes(ctx)
        dcn_bw = 5e9  # inter-site link, bytes/s
        return payload / dcn_bw

    # ------------------------------------------------------------------
    def check_trigger(self, session: AISession, zone: str,
                      triggers: MigrationTriggers) -> bool:
        """Eq. (14) evaluated against the *current* anchor."""
        if not session.committed():
            return False
        b = session.binding
        try:
            model = self.catalog.get(b.model_id, b.model_version)
        except KeyError:
            # roaming on a model this domain does not carry: no local
            # prediction basis — triggers come from the visited side
            return False
        site = self.sites[b.site_id]
        from repro.core.qos import PREMIUM, BEST_EFFORT
        klass = PREMIUM if session.asp.tier >= 2 else BEST_EFFORT
        pred = self.predictors.predict(session.asp, model, site, zone, klass)
        return triggers.should_migrate(pred.p_violate_l99,
                                       pred.p_violate_ttfb)

    # ------------------------------------------------------------------
    def migrate(self, session: AISession, zone: str) -> MigrationOutcome:
        if not session.committed():
            raise SessionError(FailureCause.POLICY_DENIAL,
                               "migration requires a committed session")
        src = session.binding.site_id
        t0 = self.clock.now()
        session.mark_migrating()
        prepared = None
        ticket: Optional[TransferTicket] = None
        two_phase = hasattr(self.transfer_fn, "begin")
        fed = self.federation
        try:
            if fed is not None:
                cands = fed.merged_discover(session, zone,
                                            exclude_sites=(src,))
            else:
                cands = discover(session.asp, self.catalog, self.sites,
                                 self.predictors, zone,
                                 analytics=self.analytics)
            target = page(session.asp, cands, exclude_sites=(src,))
            remote = fed is not None and fed.is_remote(target)
            ctx = self.context_tokens(session)
            if remote:
                # roaming handshake: visited PREPARE held through τ_mig
                prepared = fed.prepare_remote(
                    session, target, hold_s=self.timers.tau_mig,
                    context_tokens=ctx)
                payload = int(prepared.cache_bytes)
            else:
                model = target.model
                payload = model.session_state_bytes(ctx)
                prepared = self.coord.prepare(
                    model, target.site_id, zone, target.klass, slots=1,
                    cache_bytes=payload, hold_s=self.timers.tau_mig)
            # ---- state transfer under τ_mig, source still committed -----
            if two_phase:
                ticket = self.transfer_fn.begin(
                    session, self.sites[src], self.sites[target.site_id],
                    payload_bytes=payload)
                transfer_s = ticket.wire_s
            else:
                transfer_s = float(self.transfer_fn(
                    session, self.sites[src], self.sites[target.site_id]))
            if transfer_s > self.timers.tau_mig:
                raise SessionError(
                    FailureCause.STATE_TRANSFER_FAILURE,
                    f"transfer {transfer_s:.3f}s exceeds τ_mig="
                    f"{self.timers.tau_mig}s")
            self.clock.sleep(transfer_s)
            if self.clock.now() - t0 > self.timers.tau_mig:
                raise SessionError(FailureCause.DEADLINE_EXPIRY,
                                   "migration deadline expired")
            # ---- commit target, THEN the old binding is released ---------
            if remote:
                binding = fed.commit_remote(session, target, prepared)
            else:
                binding = self.coord.commit(prepared, model)
            session.bind(binding)   # make-before-break swap (session.bind)
            if ticket is not None:
                # data-plane break: source slot released, stream resumes on
                # the target plane (QoS occupancy follows the session)
                self.transfer_fn.commit(ticket)
            return MigrationOutcome(
                migrated=True, aborted=False, cause=None, from_site=src,
                to_site=target.site_id, interruption_ms=0.0,
                transfer_ms=transfer_s * 1e3,
                transfer_bytes=ticket.nbytes if ticket else 0,
                fingerprint=ticket.fingerprint if ticket else None,
                mid_stream=bool(ticket and ticket.handoff
                                and ticket.handoff.request is not None))
        except SessionError as e:
            # abort: roll back the target (leases AND provisional state),
            # keep serving on the source
            if ticket is not None:
                self.transfer_fn.abort(ticket)
            if prepared is not None:
                if getattr(prepared, "is_federated", False):
                    fed.abort_remote(prepared, reason=e.cause.value)
                else:
                    self.coord.abort(prepared)
            if session.state.value == "migrating":
                # still committed on the source ⇒ fall back without teardown
                session.state = type(session.state).COMMITTED
                session.history.append((self.clock.now(),
                                        f"migration-aborted:{e.cause.value}"))
            return MigrationOutcome(
                migrated=False, aborted=True, cause=e.cause, from_site=src,
                to_site=None, interruption_ms=0.0)
