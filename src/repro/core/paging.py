"""AI PAGING (Eq. 9): context-aware anchoring.

Selects (m*, e*) ∈ 𝒦 minimising predicted contract-violation risk

    w1·P̂[L99 > ℓ99 | m,e,ξ] + w2·P̂[Tff > ℓff | m,e,ξ]
                             + w3·P̂[migration required | m,e,ξ]

subject to the hard constraints already enforced in discovery. The risk
events are written in the exact boundary quantities the ASP constrains, so
every anchoring decision is falsifiable against Z(t) after the fact.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.asp import ASP
from repro.core.discovery import Candidate, admissible_set
from repro.core.failures import FailureCause, SessionError


@dataclass(frozen=True)
class PagingWeights:
    w1: float = 1.0     # tail-latency violation risk
    w2: float = 1.0     # TTFB violation risk
    w3: float = 0.5     # migration risk (continuity classes weight higher)
    #: home-routing bias: anchoring in another administrative domain costs
    #: an east-west handshake on every later lifecycle verb, so a visited
    #: anchor must beat the best home anchor by at least this much risk
    w_domain: float = 0.05


def risk(c: Candidate, w: PagingWeights) -> float:
    p = c.prediction
    return w.w1 * p.p_violate_l99 + w.w2 * p.p_violate_ttfb \
        + w.w3 * p.p_migration \
        + (w.w_domain if getattr(c, "domain", "") else 0.0)


def page(asp: ASP, candidates: List[Candidate], *,
         weights: Optional[PagingWeights] = None,
         exclude_sites: Tuple[str, ...] = ()) -> Candidate:
    """Pick the anchor. ``exclude_sites`` lets migration re-page away from
    the current (degraded) anchor."""
    w = weights or PagingWeights(
        w3=1.5 if asp.continuity_required() else 0.25)
    k = [c for c in admissible_set(candidates)
         if c.site_id not in exclude_sites]
    if not k:
        raise SessionError(FailureCause.NO_FEASIBLE_BINDING,
                           "admissible set empty after exclusions")
    return min(k, key=lambda c: risk(c, w))
