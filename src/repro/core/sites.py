"""Execution sites (the "execution role"): edge / regional / central anchors.

A site models one TPU slice (DESIGN.md hardware adaptation): chips, HBM,
peak FLOP/s, access RTT per zone, hosted models, and a **compute lease
table**. Leases are the v_cmp(t) side of the commitment coupling (Eq. 4/10):
a lease is provisional until confirmed, carries an expiry, and releasing it
is idempotent (two-phase rollback must never partially free).

Capacity model (what PREPARE reserves):
* decode slots — concurrent sequences the site's continuous batcher admits;
* HBM bytes    — weights (shared, refcounted) + per-session cache bytes.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.clock import Clock
from repro.core.failures import FailureCause, SessionError
from repro.core.catalog import ModelEntry


@dataclass
class ComputeLease:
    lease_id: str
    site_id: str
    model_key: str
    slots: int
    hbm_bytes: float
    expires_at: float
    confirmed: bool = False

    def valid(self, now: float) -> bool:
        return now < self.expires_at


@dataclass
class SiteSpec:
    site_id: str
    kind: str                   # edge | regional | central
    region: str                 # sovereignty region tag
    chips: int
    hbm_bytes_total: float
    peak_flops: float           # aggregate bf16
    hbm_bw: float               # aggregate bytes/s
    decode_slots: int
    #: RTT (ms) from each access zone to this site
    rtt_ms: Dict[str, float] = field(default_factory=dict)
    #: models with weights resident (model_key = "id@version")
    hosted_models: Tuple[str, ...] = ()
    #: price per chip-second (feeds Γ̂)
    price_per_chip_s: float = 1e-4


class ExecutionSite:
    """Reservation + telemetry surface of one anchor."""

    def __init__(self, spec: SiteSpec, clock: Clock):
        self.spec = spec
        self.clock = clock
        self._leases: Dict[str, ComputeLease] = {}
        self._ids = itertools.count()
        # smoothed occupancy signals (fed to analytics/NWDAF role)
        self._queue_depth = 0.0
        self._engine = None  # optional real InferenceEngine (migration plane)
        self._plane = None   # QoS-scheduled ServingPlane (repro.serving.plane)
        #: supervisor crash verdict: a dead site holds no leases (v_cmp is
        #: False for every session anchored here) and refuses PREPARE
        self.dead = False

    # ------------------------------------------------------------------
    # capacity accounting
    # ------------------------------------------------------------------
    def _gc(self) -> None:
        now = self.clock.now()
        dead = [k for k, l in self._leases.items() if not l.valid(now)]
        for k in dead:
            del self._leases[k]

    def slots_in_use(self) -> int:
        self._gc()
        return sum(l.slots for l in self._leases.values())

    def hbm_in_use(self) -> float:
        self._gc()
        return sum(l.hbm_bytes for l in self._leases.values())

    def utilization(self) -> float:
        return self.slots_in_use() / max(self.spec.decode_slots, 1)

    def hosts(self, model_key: str) -> bool:
        return model_key in self.spec.hosted_models

    # ------------------------------------------------------------------
    # lease lifecycle (v_cmp side of Eq. 4/10)
    # ------------------------------------------------------------------
    def prepare(self, model: ModelEntry, *, slots: int, cache_bytes: float,
                ttl_s: float) -> ComputeLease:
        """Provisional reservation. Raises COMPUTE_SCARCITY when the site
        cannot hold the new session without breaking existing leases."""
        self._gc()
        if self.dead:
            raise SessionError(FailureCause.COMPUTE_SCARCITY,
                               f"{self.spec.site_id}: site is dead")
        key = f"{model.model_id}@{model.version}"
        if not self.hosts(key):
            raise SessionError(FailureCause.MODEL_UNAVAILABLE,
                               f"{key} not resident on {self.spec.site_id}")
        if self.slots_in_use() + slots > self.spec.decode_slots:
            raise SessionError(FailureCause.COMPUTE_SCARCITY,
                               f"{self.spec.site_id}: decode slots exhausted")
        if self.hbm_in_use() + cache_bytes > self.spec.hbm_bytes_total:
            raise SessionError(FailureCause.COMPUTE_SCARCITY,
                               f"{self.spec.site_id}: HBM exhausted")
        lease = ComputeLease(
            lease_id=f"{self.spec.site_id}/cmp-{next(self._ids)}",
            site_id=self.spec.site_id, model_key=key, slots=slots,
            hbm_bytes=cache_bytes,
            expires_at=self.clock.now() + ttl_s)
        self._leases[lease.lease_id] = lease
        return lease

    def confirm(self, lease_id: str, *, lease_s: float) -> None:
        lease = self._leases.get(lease_id)
        if lease is None or not lease.valid(self.clock.now()):
            raise SessionError(FailureCause.DEADLINE_EXPIRY,
                               f"compute lease {lease_id} expired before COMMIT")
        lease.confirmed = True
        lease.expires_at = self.clock.now() + lease_s

    def renew(self, lease_id: str, lease_s: float) -> bool:
        lease = self._leases.get(lease_id)
        if lease is None or not lease.valid(self.clock.now()):
            return False
        lease.expires_at = self.clock.now() + lease_s
        return True

    def release(self, lease_id: str) -> None:
        """Idempotent: releasing an unknown/expired lease is a no-op."""
        self._leases.pop(lease_id, None)

    def lease_valid(self, lease_id: str) -> bool:
        lease = self._leases.get(lease_id)
        return bool(lease and lease.valid(self.clock.now()))

    # ------------------------------------------------------------------
    # supervisor lifecycle
    # ------------------------------------------------------------------
    def mark_dead(self, detail: str = "") -> None:
        """Crash: the lease table dies with the process. Every session
        anchored here instantly loses v_cmp — exactly the Eq. 4 coupling
        the supervisor's re-anchoring restores at a live site."""
        self.dead = True
        self._leases.clear()

    def mark_alive(self) -> None:
        """Recovered process: fresh lease table (nothing survives a crash);
        sessions must re-PREPARE."""
        self.dead = False
        self._leases.clear()

    # ------------------------------------------------------------------
    # service-time primitives (feed predictors)
    # ------------------------------------------------------------------
    def flops_per_chip(self) -> float:
        return self.spec.peak_flops / max(self.spec.chips, 1)

    def attach_engine(self, engine) -> None:
        self._engine = engine

    @property
    def engine(self):
        return self._engine

    def attach_plane(self, plane) -> None:
        """Every request to this site is served through this plane — the
        QoS-contract enforcement point (class ordering, premium reservation,
        deadline fast-fail) and the congestion sensor for analytics."""
        self._plane = plane

    @property
    def plane(self):
        return self._plane


def default_sites(clock: Clock, hosted: Tuple[str, ...]) -> Dict[str, ExecutionSite]:
    """A 3-tier deployment: edge (close, small), regional, central (far, big).

    Chip counts mirror the dry-run meshes: the central site is a full 16×16
    pod; the pod axis of the multi-pod mesh is what a regional+central pair
    rides."""
    mk = lambda s: ExecutionSite(s, clock)
    v5e_flops, v5e_bw, hbm = 197e12, 819e9, 16e9
    sites = [
        SiteSpec("edge-a", "edge", "eu", chips=16,
                 hbm_bytes_total=16 * hbm, peak_flops=16 * v5e_flops,
                 hbm_bw=16 * v5e_bw, decode_slots=64,
                 rtt_ms={"zone-a": 2.0, "zone-b": 9.0, "zone-c": 18.0},
                 hosted_models=hosted, price_per_chip_s=2.0e-4),
        SiteSpec("edge-b", "edge", "eu", chips=16,
                 hbm_bytes_total=16 * hbm, peak_flops=16 * v5e_flops,
                 hbm_bw=16 * v5e_bw, decode_slots=64,
                 rtt_ms={"zone-a": 9.0, "zone-b": 2.0, "zone-c": 10.0},
                 hosted_models=hosted, price_per_chip_s=2.0e-4),
        SiteSpec("regional-1", "regional", "eu", chips=64,
                 hbm_bytes_total=64 * hbm, peak_flops=64 * v5e_flops,
                 hbm_bw=64 * v5e_bw, decode_slots=384,
                 rtt_ms={"zone-a": 12.0, "zone-b": 12.0, "zone-c": 12.0},
                 hosted_models=hosted, price_per_chip_s=1.2e-4),
        SiteSpec("central-1", "central", "us", chips=256,
                 hbm_bytes_total=256 * hbm, peak_flops=256 * v5e_flops,
                 hbm_bw=256 * v5e_bw, decode_slots=2048,
                 rtt_ms={"zone-a": 55.0, "zone-b": 55.0, "zone-c": 55.0},
                 hosted_models=hosted, price_per_chip_s=0.8e-4),
    ]
    return {s.site_id: mk(s) for s in sites}
