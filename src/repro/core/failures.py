"""Failure semantics: Eq. (12) cause partition + Eq. (11) deadline ordering.

The cause set extends the paper's nine-element partition with the two causes
an *unreliable control plane* forces into the contract: at-least-once
transports fail (TRANSPORT_FAILURE) and budgets shrink hop by hop until work
becomes infeasible (DEADLINE_EXCEEDED).  Each element implies a distinct
remediation path and must not be conflated with others; RETRYABLE partitions
the set into the causes a caller may retry against the same contract versus
those that require a changed request.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass


class FailureCause(enum.Enum):
    """Eq. (12): the compact semantic partition sufficient for diagnosis.

    The first nine members are the paper's partition verbatim; the last two
    are the unreliable-transport extension (lost/failed delivery, and a
    propagated deadline budget that no hop could meet).
    """
    CONSENT_VIOLATION = "consent violation"
    POLICY_DENIAL = "policy denial"
    SOVEREIGNTY_VIOLATION = "sovereignty violation"
    MODEL_UNAVAILABLE = "model unavailable"
    NO_FEASIBLE_BINDING = "no feasible binding"
    COMPUTE_SCARCITY = "compute scarcity"
    QOS_SCARCITY = "QoS scarcity"
    STATE_TRANSFER_FAILURE = "state transfer failure"
    DEADLINE_EXPIRY = "deadline expiry"
    TRANSPORT_FAILURE = "transport failure"
    DEADLINE_EXCEEDED = "deadline exceeded"


#: remediation class per cause — used by the orchestrator's retry logic and
#: asserted distinct in tests (causes must not be conflated).
REMEDIATION = {
    FailureCause.CONSENT_VIOLATION: "re-acquire resource-owner authorization",
    FailureCause.POLICY_DENIAL: "revise ASP cost envelope / tier",
    FailureCause.SOVEREIGNTY_VIOLATION: "restrict discovery to allowed regions",
    FailureCause.MODEL_UNAVAILABLE: "fall back along the ASP ladder",
    FailureCause.NO_FEASIBLE_BINDING: "relax objectives or widen fallback ladder",
    FailureCause.COMPUTE_SCARCITY: "retry with backoff on alternate anchor",
    FailureCause.QOS_SCARCITY: "retry with best-effort consent or new path",
    FailureCause.STATE_TRANSFER_FAILURE: "abort migration, keep source anchor",
    FailureCause.DEADLINE_EXPIRY: "abort phase, roll back provisional leases",
    FailureCause.TRANSPORT_FAILURE:
        "retry same target with backoff (at-least-once delivery)",
    FailureCause.DEADLINE_EXCEEDED:
        "stop retrying; re-issue with a larger deadline budget",
}


#: Causes a caller may retry without changing the request: the contract is
#: intact, only the attempt failed.  Everything else is terminal for the
#: request as issued — retrying verbatim would deterministically fail again
#: (policy/consent/sovereignty) or waste the remaining budget
#: (DEADLINE_EXCEEDED means the budget itself is what ran out).
RETRYABLE = frozenset({
    FailureCause.COMPUTE_SCARCITY,
    FailureCause.QOS_SCARCITY,
    FailureCause.DEADLINE_EXPIRY,
    FailureCause.TRANSPORT_FAILURE,
})


def is_retryable(cause: FailureCause) -> bool:
    """True when a fresh attempt at the same request can still succeed."""
    return cause in RETRYABLE


class SessionError(Exception):
    def __init__(self, cause: FailureCause, detail: str = ""):
        self.cause = cause
        self.detail = detail
        super().__init__(f"{cause.value}: {detail}" if detail else cause.value)


@dataclass(frozen=True)
class Timers:
    """Eq. (11): phase deadlines (seconds).

    Ordering constraint: τ_disc ≤ τ_page ≤ τ_prep ≤ τ_com and
    τ_mig ≤ min(T_max, lease).
    """
    tau_disc: float = 0.05
    tau_page: float = 0.05
    tau_prep: float = 0.20
    tau_com: float = 0.20
    tau_mig: float = 2.0
    lease_s: float = 30.0       # validity lease for both commitments

    def validate(self, t_max_s: float) -> None:
        if not (self.tau_disc <= self.tau_page <= self.tau_prep <= self.tau_com):
            raise ValueError(
                f"Eq.(11) violated: need τ_disc ≤ τ_page ≤ τ_prep ≤ τ_com, "
                f"got {self.tau_disc}, {self.tau_page}, {self.tau_prep}, "
                f"{self.tau_com}")
        if self.tau_mig > min(t_max_s, self.lease_s):
            raise ValueError(
                f"Eq.(11) violated: τ_mig={self.tau_mig} must be ≤ "
                f"min(T_max={t_max_s}, lease={self.lease_s})")
