"""QoS-aware continuous-batching scheduler — the transport-contract
enforcement point inside the serving plane.

Maps AIS QoS flows onto decode-slot scheduling:

* **Priority classes** mirror the QFI classes (premium / assured /
  best-effort): admission to the next decode round drains queues in strict
  class order, FIFO within a class (weighted-fair would starve tails the
  ASP measures, so strict+reservation is the enforceable choice).
* **Reserved share**: a fraction of slots only premium flows may hold —
  this is what a confirmed QoS lease actually buys at the engine.
* **Deadline-aware cutoffs** (straggler mitigation, serving side): a request
  whose ASP T_max would expire before its predicted completion is failed
  FAST with DEADLINE_EXPIRY instead of occupying a slot to produce a
  late-useless answer ("served-and-failed" accounting in the §V sense).
"""

from __future__ import annotations

import collections
import itertools
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Union

from repro.core.clock import Clock
from repro.core.failures import FailureCause

_CLASS_ORDER = ("premium", "assured", "best-effort")


@dataclass
class Request:
    request_id: str
    session_id: str
    klass: str                  # premium | assured | best-effort
    prompt_tokens: int
    gen_tokens: int
    t_max_ms: float
    submitted_at: float = 0.0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    failed: Optional[FailureCause] = None
    #: optional service-time hints (per-request predictor output); consumed
    #: by SimulatedEngine backends and by deadline fast-fail when present
    hint_ttfb_ms: Optional[float] = None
    hint_total_ms: Optional[float] = None
    #: optional caller-supplied prompt tokens (real-engine backends); when
    #: None the backend synthesizes a deterministic prompt
    prompt: Optional[object] = None
    #: continue a bound (parked / hibernated) session's generation instead
    #: of superseding its state with a fresh prefill
    resume: bool = False
    #: tenant adapter the session is bound to ("" = base model); consumed
    #: by real-engine backends at prefill admission
    adapter_id: str = ""

    def wait_ms(self, now: float) -> float:
        return (now - self.submitted_at) * 1e3


@dataclass
class SchedulerStats:
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    fast_failed: int = 0
    rejected: int = 0           # plane-level admission denials (loss systems)
    per_class_wait_ms: Dict[str, List[float]] = field(
        default_factory=lambda: collections.defaultdict(list))

    def p_wait_ms(self, klass: str, q: float) -> float:
        """Order-statistic quantile of admission wait for one class."""
        waits = sorted(self.per_class_wait_ms.get(klass, ()))
        if not waits:
            return 0.0
        idx = min(len(waits) - 1, int(q * (len(waits) - 1) + 0.5))
        return waits[idx]


class QoSScheduler:
    def __init__(self, clock: Clock, *, slots: int,
                 premium_reserved_frac: float = 0.25):
        self.clock = clock
        self.slots = slots
        self.premium_reserved = max(1, int(slots * premium_reserved_frac)) \
            if slots > 1 and premium_reserved_frac > 0 else 0
        self.queues: Dict[str, Deque[Request]] = {
            k: collections.deque() for k in _CLASS_ORDER}
        self.running: Dict[str, Request] = {}
        self.stats = SchedulerStats()
        self._ids = itertools.count()

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.submitted_at = self.clock.now()
        self.stats.submitted += 1
        self.queues[req.klass].append(req)

    def _slots_usable(self, klass: str) -> int:
        """Best-effort/assured may not dip into the premium reservation."""
        in_use = len(self.running)
        free = self.slots - in_use
        if klass == "premium":
            return free
        premium_running = sum(1 for r in self.running.values()
                              if r.klass == "premium")
        reserve_hold = max(0, self.premium_reserved - premium_running)
        return max(0, free - reserve_hold)

    def _deadline_hopeless(self, req: Request,
                           predicted_service_ms: float) -> bool:
        waited_ms = (self.clock.now() - req.submitted_at) * 1e3
        return waited_ms + predicted_service_ms > req.t_max_ms

    # ------------------------------------------------------------------
    def next_batch(self, *,
                   predicted_service_ms: Union[float,
                                               Callable[[Request], float]]
                   = 0.0,
                   skip: Optional[Callable[[Request], bool]] = None,
                   on_fast_fail: Optional[Callable[[Request], None]] = None
                   ) -> List[Request]:
        """Admit requests to the next decode round in class order.

        ``predicted_service_ms`` may be a scalar or a per-request predictor
        (the serving plane passes the backend's estimate so deadline fast-fail
        accounts for each request's own work). ``skip`` defers a request
        without consuming it (e.g. its session already holds an engine slot) —
        FIFO order within the class is preserved by stopping at the first
        skipped head. ``on_fast_fail`` lets the plane record DEADLINE_EXPIRY
        drops as served-and-failed results.
        """
        admitted: List[Request] = []
        for klass in _CLASS_ORDER:
            q = self.queues[klass]
            while q and self._slots_usable(klass) > 0:
                if skip is not None and skip(q[0]):
                    break               # head-of-line blocked; next class
                req = q.popleft()
                svc = predicted_service_ms(req) \
                    if callable(predicted_service_ms) else predicted_service_ms
                if svc and self._deadline_hopeless(req, svc):
                    req.failed = FailureCause.DEADLINE_EXPIRY
                    req.finished_at = self.clock.now()
                    self.stats.fast_failed += 1
                    if on_fast_fail is not None:
                        on_fast_fail(req)
                    continue
                req.started_at = self.clock.now()
                self.running[req.request_id] = req
                self.stats.admitted += 1
                self.stats.per_class_wait_ms[klass].append(
                    (req.started_at - req.submitted_at) * 1e3)
                admitted.append(req)
        return admitted

    def complete(self, request_id: str) -> None:
        req = self.running.pop(request_id, None)
        if req:
            req.finished_at = self.clock.now()
            self.stats.completed += 1

    # ------------------------------------------------------------------
    # make-before-break handover (migration data plane)
    # ------------------------------------------------------------------
    def detach(self, request_id: str) -> Optional[Request]:
        """Remove a running request WITHOUT completion accounting: the
        request is being handed over to another plane's scheduler (its slot
        here frees immediately; the occupancy follows the session)."""
        return self.running.pop(request_id, None)

    def attach(self, req: Request) -> None:
        """Install an in-flight request admitted on another plane. The slot
        is occupied immediately; admission-wait was already measured at the
        original admission, so no wait statistics are recorded here."""
        self.running[req.request_id] = req

    def take_queued(self, session_id: str) -> List[Request]:
        """Remove and return this session's queued (not yet admitted)
        requests, preserving FIFO order within each class — they follow
        the session to its new anchor instead of being served here."""
        taken: List[Request] = []
        for q in self.queues.values():
            if any(r.session_id == session_id for r in q):
                taken.extend(r for r in q if r.session_id == session_id)
                kept = [r for r in q if r.session_id != session_id]
                q.clear()
                q.extend(kept)
        return taken

    def put_queued(self, reqs: List[Request]) -> None:
        """Enqueue requests handed over from another plane, preserving
        their original submit times (no resubmission accounting)."""
        for r in reqs:
            self.queues[r.klass].append(r)

    def queue_depth(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def queue_depths(self) -> Dict[str, int]:
        return {k: len(q) for k, q in self.queues.items()}
