"""NE-AIaaS serving front: binds the control plane (Orchestrator) to real
engines at the execution sites.

``AIaaSServer`` owns per-(site, model) engines, attaches them to the
ExecutionSite objects so ``Orchestrator.serve`` hits real prefill/decode,
and implements the engine-level migration data plane used by the
MigrationController (make-before-break with fingerprint verification).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.catalog import Catalog
from repro.core.orchestrator import Orchestrator
from repro.core.session import AISession
from repro.serving.engine import InferenceEngine
from repro.serving import state_transfer


class EngineFleet:
    """Per-site engines for one model (shared weights across sites)."""

    def __init__(self, catalog: Catalog, model_id: str, *, slots: int = 8,
                 max_len: int = 256):
        entry = catalog.get(model_id)
        self.entry = entry
        self.slots = slots
        self.max_len = max_len
        self._engines: Dict[str, InferenceEngine] = {}
        self._params = None

    def engine_for(self, site_id: str) -> InferenceEngine:
        if site_id not in self._engines:
            eng = InferenceEngine(self.entry.cfg, params=self._params,
                                  slots=self.slots, max_len=self.max_len)
            self._params = eng.params   # weights shared across sites
            self._engines[site_id] = eng
        return self._engines[site_id]


class AIaaSServer:
    def __init__(self, orch: Orchestrator, model_id: str = "edge-tiny",
                 *, slots: int = 8, max_len: int = 256):
        self.orch = orch
        self.fleet = EngineFleet(orch.catalog, model_id, slots=slots,
                                 max_len=max_len)
        for site_id, site in orch.sites.items():
            site.attach_engine(self.fleet.engine_for(site_id))
        # engine-level data plane for make-before-break migration
        orch.migrations.transfer_fn = self._transfer

    def _transfer(self, session: AISession, src_site, dst_site) -> float:
        src = self.fleet.engine_for(src_site.spec.site_id)
        dst = self.fleet.engine_for(dst_site.spec.site_id)
        if session.session_id in src._slot_map:
            meta = state_transfer.transfer(src, dst, session.session_id)
            return meta["wire_s_at_link"]
        return 0.0

    # ------------------------------------------------------------------
    def request(self, session: AISession, prompt: np.ndarray,
                gen_tokens: int = 16) -> dict:
        site = self.orch.sites[session.binding.site_id]
        eng = self.fleet.engine_for(site.spec.site_id)
        out = eng.serve(session.session_id, len(prompt), gen_tokens,
                        prompt=prompt)
        from repro.core.telemetry import RequestRecord
        self.orch.telemetry[session.session_id].record(RequestRecord(
            t_submit=self.orch.clock.now(), ttfb_ms=out["ttfb_ms"],
            latency_ms=out["latency_ms"],
            completed=out["latency_ms"]
            <= session.asp.objectives.t_max_ms,
            tokens=gen_tokens))
        self.orch.policy.meter(session.charging_ref, tokens=gen_tokens,
                               chip_s=out["latency_ms"] / 1e3,
                               unit_price=self.fleet.entry.price_per_1k_tokens)
        return out
