"""NE-AIaaS serving front: binds the control plane (Orchestrator) to real
engines at the execution sites, behind QoS-scheduled serving planes.

``AIaaSServer`` owns per-(site, model) engines, wraps each in a
:class:`~repro.serving.plane.ServingPlane` attached to the ExecutionSite —
so ``Orchestrator.serve`` goes through class-ordered slot admission with
premium reservation and deadline fast-fail — and implements the engine-level
migration data plane used by the MigrationController (make-before-break with
fingerprint verification).
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.catalog import Catalog
from repro.core.orchestrator import Orchestrator
from repro.core.session import AISession
from repro.serving.engine import InferenceEngine
from repro.serving.plane import (RealEngineBackend, ServingPlane,
                                 PlaneResult)
from repro.serving.scheduler import Request


class EngineFleet:
    """Per-site engines for one model (shared weights across sites)."""

    def __init__(self, catalog: Catalog, model_id: str, *, slots: int = 8,
                 max_len: int = 256):
        entry = catalog.get(model_id)
        self.entry = entry
        self.slots = slots
        self.max_len = max_len
        self._engines: Dict[str, InferenceEngine] = {}
        self._params = None

    def engine_for(self, site_id: str) -> InferenceEngine:
        if site_id not in self._engines:
            eng = InferenceEngine(self.entry.cfg, params=self._params,
                                  slots=self.slots, max_len=self.max_len)
            self._params = eng.params   # weights shared across sites
            self._engines[site_id] = eng
        return self._engines[site_id]


class AIaaSServer:
    def __init__(self, orch: Orchestrator, model_id: str = "edge-tiny",
                 *, slots: int = 8, max_len: int = 256,
                 premium_reserved_frac: float = 0.25):
        self.orch = orch
        self.fleet = EngineFleet(orch.catalog, model_id, slots=slots,
                                 max_len=max_len)
        self.planes: Dict[str, ServingPlane] = {}
        for site_id, site in orch.sites.items():
            eng = self.fleet.engine_for(site_id)
            site.attach_engine(eng)     # migration data plane + direct access
            plane = ServingPlane(
                orch.clock, RealEngineBackend(eng, orch.clock),
                slots=slots, premium_reserved_frac=premium_reserved_frac,
                site_id=site_id)
            site.attach_plane(plane)
            self.planes[site_id] = plane
        # make-before-break migration rides the orchestrator's default
        # PlaneTransferPath, which resolves these attached planes: export on
        # the source engine → fingerprint-verified import on the target →
        # mid-stream requests keep streaming on the target after the swap

    # ------------------------------------------------------------------
    def submit(self, session: AISession, *, prompt_tokens: int = 16,
               gen_tokens: int = 16,
               prompt: Optional[np.ndarray] = None) -> Optional[Request]:
        """Async path: enqueue on the anchor site's plane (QoS class from
        the binding's QFI); drive with ``drain()``."""
        plane = self.planes[session.binding.site_id]
        klass = self.orch.qos_class(session)
        return plane.submit(
            session_id=session.session_id, klass=klass.name,
            prompt_tokens=len(prompt) if prompt is not None else prompt_tokens,
            gen_tokens=gen_tokens,
            t_max_ms=session.asp.objectives.t_max_ms, prompt=prompt)

    def drain(self) -> Dict[str, PlaneResult]:
        """Run every plane to completion; telemetry + charging recorded by
        the orchestrator's single recorder (exactly once per request)."""
        out: Dict[str, PlaneResult] = {}
        for site_id, plane in self.planes.items():
            plane.drain()
            for res in self.orch.record_results(self.orch.sites[site_id]):
                out[res.request_id] = res
        return out

    # ------------------------------------------------------------------
    def request(self, session: AISession, prompt: np.ndarray,
                gen_tokens: int = 16) -> dict:
        """Unary path kept for compatibility: serve one request through the
        plane synchronously, on the CALLER's prompt, returning the engine's
        generated token ids and timings (engine.serve-style)."""
        site = self.orch.sites[session.binding.site_id]
        plane = self.planes[session.binding.site_id]
        klass = self.orch.qos_class(session)
        res = plane.serve(
            session_id=session.session_id, klass=klass.name,
            prompt_tokens=len(prompt), gen_tokens=gen_tokens,
            t_max_ms=session.asp.objectives.t_max_ms,
            prompt=np.asarray(prompt, np.int32))
        self.orch.record_results(site)
        return {"tokens": res.token_ids or [], "ttfb_ms": res.ttfb_ms,
                "latency_ms": res.latency_ms}
