"""NE-AIaaS serving front: binds the control plane to real engines at the
execution sites, behind QoS-scheduled serving planes, and exposes them
northbound.

``AIaaSServer`` owns per-(site, model) engines, wraps each in a
:class:`~repro.serving.plane.ServingPlane` attached to the ExecutionSite —
so every serve goes through class-ordered slot admission with premium
reservation and deadline fast-fail — and fronts the whole deployment with a
:class:`~repro.api.gateway.NorthboundGateway`: the server's own submit /
request / drain paths are gateway message flows, so the in-process driver
exercises the exact surface a remote invoker would.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.api import messages as wire
from repro.api.gateway import NorthboundGateway
from repro.core.catalog import Catalog
from repro.core.orchestrator import Orchestrator
from repro.core.session import AISession
from repro.serving.engine import InferenceEngine
from repro.serving.plane import (RealEngineBackend, ServingPlane,
                                 PlaneResult)


class EngineFleet:
    """Per-site engines for one model (shared weights across sites)."""

    def __init__(self, catalog: Catalog, model_id: str, *, slots: int = 8,
                 max_len: int = 256, pallas_decode: bool = False):
        import dataclasses
        entry = catalog.get(model_id)
        self.entry = entry
        self.cfg = entry.cfg
        if pallas_decode:
            self.cfg = dataclasses.replace(entry.cfg, use_pallas_decode=True)
        self.slots = slots
        self.max_len = max_len
        self._engines: Dict[str, InferenceEngine] = {}
        self._params = None

    def engine_for(self, site_id: str) -> InferenceEngine:
        if site_id not in self._engines:
            eng = InferenceEngine(self.cfg, params=self._params,
                                  slots=self.slots, max_len=self.max_len)
            self._params = eng.params   # weights shared across sites
            self._engines[site_id] = eng
        return self._engines[site_id]


class AIaaSServer:
    def __init__(self, orch: Orchestrator, model_id: str = "edge-tiny",
                 *, slots: int = 8, max_len: int = 256,
                 premium_reserved_frac: float = 0.25,
                 gateway: Optional[NorthboundGateway] = None,
                 decode_chunk: Optional[Dict[str, int]] = None,
                 pallas_decode: bool = False):
        self.orch = orch
        self.fleet = EngineFleet(orch.catalog, model_id, slots=slots,
                                 max_len=max_len, pallas_decode=pallas_decode)
        self.planes: Dict[str, ServingPlane] = {}
        for site_id, site in orch.sites.items():
            eng = self.fleet.engine_for(site_id)
            site.attach_engine(eng)     # migration data plane + direct access
            plane = ServingPlane(
                orch.clock, RealEngineBackend(eng, orch.clock),
                slots=slots, premium_reserved_frac=premium_reserved_frac,
                site_id=site_id, decode_chunk=decode_chunk)
            site.attach_plane(plane)
            self.planes[site_id] = plane
        # the northbound exposure point: sessions established through it and
        # sessions established directly on the orchestrator serve identically
        self.gateway = gateway if gateway is not None \
            else NorthboundGateway(orch)
        # fleet-ops layer: per-site liveness/readiness, graceful drain,
        # crash detection + re-anchoring (repro.serving.supervisor)
        from repro.serving.supervisor import FleetSupervisor
        self.supervisor = FleetSupervisor(orch)
        # make-before-break migration rides the orchestrator's default
        # PlaneTransferPath, which resolves these attached planes: export on
        # the source engine → fingerprint-verified import on the target →
        # mid-stream requests keep streaming on the target after the swap

    # ------------------------------------------------------------------
    def submit(self, session: AISession, *, prompt_tokens: int = 16,
               gen_tokens: int = 16,
               prompt: Optional[np.ndarray] = None) -> Optional[str]:
        """Async path through the gateway: enqueue on the anchor site's
        plane (QoS class from the binding's QFI); drive with ``drain()``.
        Returns the request id, or None when admission control rejects."""
        ack = self.gateway.submit(wire.ServeRequest(
            session_id=session.session_id,
            prompt_tokens=len(prompt) if prompt is not None else prompt_tokens,
            gen_tokens=gen_tokens,
            prompt=[int(t) for t in prompt] if prompt is not None else None,
            stream=False))
        return ack.request_id if ack.accepted else None

    def drain(self) -> Dict[str, PlaneResult]:
        """Run every plane to completion through the gateway; telemetry +
        charging recorded by the orchestrator's single recorder (exactly
        once per request)."""
        out: Dict[str, PlaneResult] = {}
        for res in self.gateway.drain():
            out[res.request_id] = PlaneResult(
                request_id=res.request_id, session_id=res.session_id,
                klass=res.klass, ttfb_ms=res.ttfb_ms,
                latency_ms=res.latency_ms, queue_wait_ms=res.queue_wait_ms,
                tokens=res.tokens, completed=res.completed,
                failed=wire.cause_for_code(res.error_code)
                if res.error_code else None,
                token_ids=res.token_ids, prompt_tokens=res.prompt_tokens)
        return out

    # ------------------------------------------------------------------
    def request(self, session: AISession, prompt: np.ndarray,
                gen_tokens: int = 16) -> dict:
        """Unary path kept for compatibility: one streamed serve through
        the gateway on the CALLER's prompt, returning the engine's generated
        token ids and timings (engine.serve-style)."""
        frames = list(self.gateway.serve_stream(wire.ServeRequest(
            session_id=session.session_id,
            prompt_tokens=len(prompt), gen_tokens=gen_tokens,
            prompt=[int(t) for t in np.asarray(prompt)])))
        done = frames[-1]
        if isinstance(done, wire.ErrorResponse):
            from repro.api.client import raise_for
            raise_for(done)
        return {"tokens": done.token_ids or [], "ttfb_ms": done.ttfb_ms,
                "latency_ms": done.latency_ms}
