"""Per-site ServingPlane: the single path every request takes to an anchor.

The paper's AIS contract binds transport QoS to execution placement with
enforceable tail-latency semantics; this module is where the enforcement
actually happens. One plane per execution site owns

* a :class:`QoSScheduler` — class-ordered slot admission (premium slot
  reservation, deadline fast-fail with served-and-failed accounting), and
* a backend behind a common interface:
    - :class:`RealEngineBackend` — the continuous-batching
      :class:`~repro.serving.engine.InferenceEngine` (decode rounds across
      sessions, not per-request loops), or
    - :class:`SimulatedEngine` — service times drawn from a sampler
      (predictor output or the §V ``LatencyModel``) under a
      :class:`~repro.core.clock.VirtualClock`, which is what lets the
      control-plane tests and the Monte-Carlo scenarios exercise the *same*
      queueing machinery the real engine runs behind.

Request lifecycle (event-driven)::

    submit ──► class queue ──► slot admission ──► decode rounds ──► complete
                  │   (premium reservation,          (real engine) │
                  │    deadline fast-fail)    or completion event  │
                  └────────── rejected (loss-system planes) ───────┘

The plane is also the congestion sensor for the NWDAF-style analytics loop:
``load()`` exposes measured queue depth per slot and the arrival rate, which
``Orchestrator.heartbeat`` feeds into ``Analytics.observe_site`` so paging
(Eq. 9) and migration triggers (Eq. 14) react to real load.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
import zlib
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.clock import Clock, VirtualClock
from repro.core.failures import FailureCause
from repro.serving.scheduler import QoSScheduler, Request


@dataclass
class PlaneResult:
    """Boundary-observable outcome of one request through the plane."""
    request_id: str
    session_id: str
    klass: str
    ttfb_ms: float
    latency_ms: float            # submit → completion (includes queue wait)
    queue_wait_ms: float
    tokens: int
    completed: bool              # finished within the request's T_max
    failed: Optional[FailureCause] = None
    token_ids: Optional[List[int]] = None   # real-engine backends only
    prompt_tokens: int = 0       # context consumed (sizes migration payload)


@dataclass
class PlaneLoad:
    """Congestion snapshot ξ-side: what analytics ingests per heartbeat."""
    queue_depth: float           # waiting requests per slot
    arrival_rate: float          # submits / s over the recent window
    running: int
    slots: int
    utilization: float
    #: session-tier occupancy (real-engine backends with hibernation):
    #: bound = resident + hibernated; page_util feeds the Eq. 14 memory-
    #: pressure term so migration triggers see pool exhaustion coming
    resident_sessions: int = 0
    hibernated_sessions: int = 0
    bound_sessions: int = 0
    page_util: float = 0.0
    #: refused hibernation puts (capacity-bounded store): back-pressure the
    #: supervisor reads instead of the tick crashing with MemoryError
    store_full: int = 0


@dataclass
class Admission:
    """Backend's answer to 'start serving this request now'."""
    ttfb_ms: float
    finish_at: Optional[float]   # absolute clock time (simulated backends)
    first_token: Optional[int] = None
    #: the request continues an existing bound session (no prefill ran, so
    #: there is no first token — generation resumes at the next round)
    resumed: bool = False


@dataclass
class SessionHandoff:
    """A session's in-flight work detached from one plane for
    make-before-break handover to another: the running request keeps
    streaming on the target, queued requests re-queue there."""
    session_id: str
    request: Optional[object]              # scheduler Request, if in flight
    tokens: int = 0                        # generated so far
    token_ids: Optional[List[int]] = None  # real-engine backends
    finish_at: Optional[float] = None      # pending event (simulated)
    queued: List[object] = dataclasses.field(default_factory=list)

    def empty(self) -> bool:
        return self.request is None and not self.queued


class RealEngineBackend:
    """Continuous-batching decode rounds on a real ``InferenceEngine``.

    Requests from different sessions share decode rounds; a request finishes
    when its token budget is generated. Service-time prediction for deadline
    fast-fail comes from a measured per-token EWMA (no static assumption).
    Sessions are exclusive: the engine keys slots by session id, so at most
    one request per session is in flight (the plane defers the rest).
    """

    exclusive_sessions = True
    #: real engines measure their own service times (per-token EWMA) — the
    #: control plane never needs to supply predictor hints
    needs_service_hints = False

    def __init__(self, engine, clock: Clock, *, seed: int = 0,
                 retain_sessions: Optional[bool] = None,
                 free_page_watermark: float = 0.25,
                 hibernate_idle_s: Optional[float] = None):
        """``retain_sessions`` keeps a session's engine state bound after its
        request completes (parked, then hibernated under pressure or after
        ``hibernate_idle_s`` of idleness) so a later ``resume=True`` request
        continues the generation; defaults to on exactly when the engine has
        a hibernation store. ``free_page_watermark`` is the free-page
        fraction below which ``ensure_capacity`` starts hibernating coldest
        parked sessions pre-emptively."""
        self.engine = engine
        self.clock = clock
        if getattr(engine, "clock", None) is None:
            # thread the plane clock through so the engine's own hibernation
            # paths (page reclaim) stamp records with real times too
            engine.clock = clock
        self._ms_per_token: float = 0.0       # measured EWMA (per decode step)
        self._seed = seed
        self.retain_sessions = (
            getattr(engine, "hibernation", None) is not None
            if retain_sessions is None else bool(retain_sessions))
        self.free_page_watermark = free_page_watermark
        self.hibernate_idle_s = hibernate_idle_s
        self._parked_at: Dict[str, float] = {}

    # -- plane interface -------------------------------------------------
    def predicted_service_ms(self, req: Request) -> float:
        if req.hint_total_ms is not None:
            return req.hint_total_ms
        return self._ms_per_token * req.gen_tokens

    def _store(self):
        """Engine hibernation store, or None (also for duck-typed stubs)."""
        return getattr(self.engine, "hibernation", None)

    def _page_pressure(self) -> bool:
        eng = self.engine
        if not getattr(eng, "paged", False):
            return False
        return eng.free_pages() < self.free_page_watermark * eng.total_pages()

    def _coldest_parked(self, exclude) -> Optional[str]:
        best, victim = None, None
        for s in self.engine._slots:
            if s is not None and s.parked and s.session_id not in exclude \
                    and (best is None or s.last_used < best):
                best, victim = s.last_used, s.session_id
        return victim

    def ensure_capacity(self, active_sessions) -> None:
        """Make room for the next admission instead of refusing it: while
        there is no free slot or the page pool sits below its free-page
        watermark, hibernate (or, storeless, release) the coldest parked
        session. Falls back to the legacy orphan-slot reclaim — state
        imported by migration whose session is now submitting fresh
        requests is superseded, never left to block admission forever."""
        eng = self.engine
        for _ in range(eng.slots + 1):
            if eng.free_slots() > 0 and not self._page_pressure():
                return
            victim = self._coldest_parked(active_sessions)
            if victim is None:
                break
            if self._store() is not None:
                if not eng.hibernate_slot(victim):
                    break       # store full: fall through to orphan reclaim
            else:
                eng.release_slot(victim)
            self._parked_at.pop(victim, None)
        if eng.free_slots() == 0:
            for sid in list(eng._slot_map):
                if sid not in active_sessions:
                    eng.release_slot(sid)
                    return

    def admit(self, req: Request, now: float) -> Admission:
        eng = self.engine
        if getattr(req, "resume", False) and (
                eng.has_slot(req.session_id)
                or eng.has_hibernated(req.session_id)):
            # transparent resume: unpark is free, hibernated state
            # re-imports through the same admission path migration uses
            # (ensure_capacity already made room)
            t0 = self.clock.now()
            eng.resume_session(req.session_id)
            self._parked_at.pop(req.session_id, None)
            return Admission(ttfb_ms=(self.clock.now() - t0) * 1e3,
                             finish_at=None, resumed=True)
        if req.session_id in self.engine._slot_map:
            # stale slot from a migrated/abandoned generation: superseded
            self.engine.release_slot(req.session_id)
        elif self._store() is not None:
            self._store().drop(req.session_id)      # superseded cold state
        self._parked_at.pop(req.session_id, None)
        prompt = req.prompt
        if prompt is None:
            # crc32, not hash(): hash() varies per process under
            # PYTHONHASHSEED, which would break reproducible traces and
            # cross-process migration fingerprint checks
            rng = np.random.default_rng(
                (zlib.crc32(req.session_id.encode())
                 ^ zlib.crc32(req.request_id.encode()) ^ self._seed)
                % 2**31)
            prompt = rng.integers(
                0, self.engine.cfg.vocab_size,
                size=max(req.prompt_tokens, 1)).astype(np.int32)
        aid = getattr(req, "adapter_id", "")
        if aid:
            out = self.engine.prefill_session(req.session_id, prompt,
                                              adapter_id=aid)
        else:
            out = self.engine.prefill_session(req.session_id, prompt)
        return Admission(ttfb_ms=out["ttfb_ms"], finish_at=None,
                         first_token=out["first_token"])

    def decode_round(self, steps: Optional[int] = None):
        """One decode chunk. ``steps=None`` keeps the legacy single-step
        {session: token} form; ``steps=K`` returns {session: [K tokens]}
        from one fused dispatch.

        The service-time EWMA normalises by the tokens each active session
        emitted in the chunk (= the number of decode steps) — NOT by the
        number of sessions or calls — so ``predicted_service_ms`` (per-token
        EWMA × requested tokens) stays calibrated for deadline fast-fail
        whatever the chunk size: a request's G tokens always take G steps,
        however many sessions share each step."""
        t0 = self.clock.now()
        out = self.engine.decode_round(steps=steps)
        dt_ms = (self.clock.now() - t0) * 1e3
        if out:
            per_tok = dt_ms / max(steps or 1, 1)
            self._ms_per_token = per_tok if self._ms_per_token == 0.0 \
                else 0.8 * self._ms_per_token + 0.2 * per_tok
        return out

    def release(self, session_id: str) -> None:
        if self.retain_sessions and self.engine.has_slot(session_id):
            # keep the session bound: park now (state frozen in place),
            # hibernate later under page pressure or the idle-TTL tick
            self.engine.park_slot(session_id)
            self._parked_at[session_id] = self.clock.now()
        else:
            self.engine.release_slot(session_id)

    def tick(self, now: Optional[float] = None) -> int:
        """Idle-TTL policy (the AIS lease-expiry analogue): hibernate
        sessions parked longer than ``hibernate_idle_s``. Returns the
        number hibernated; the plane calls this from ``load()`` so the
        policy advances with every heartbeat."""
        if self.hibernate_idle_s is None or self._store() is None:
            return 0
        now = self.clock.now() if now is None else now
        n = 0
        for sid, t in list(self._parked_at.items()):
            if not self.engine.is_parked(sid):
                self._parked_at.pop(sid, None)      # reclaimed elsewhere
            elif now - t >= self.hibernate_idle_s:
                if not self.engine.hibernate_slot(sid, now=now):
                    continue    # store full: stays parked, retried next tick
                self._parked_at.pop(sid, None)
                n += 1
        return n

    def occupancy(self) -> Dict[str, float]:
        eng = self.engine
        if not hasattr(eng, "resident_sessions"):   # duck-typed stubs
            return {}
        store = self._store()
        return {"resident_sessions": eng.resident_sessions(),
                "hibernated_sessions": eng.hibernated_sessions(),
                "bound_sessions": eng.bound_sessions(),
                "page_util": eng.page_util(),
                # `is not None`, not truthiness: an EMPTY store is falsy
                # (__len__) yet its refusal count is exactly what matters
                "store_full": getattr(store, "store_full", 0)
                if store is not None else 0}

    # -- migration data plane (engine slot protocol) ---------------------
    def has_slot(self, session_id: str) -> bool:
        return self.engine.has_slot(session_id)

    def export_slot(self, session_id: str):
        return self.engine.export_slot(session_id)

    def import_slot(self, session_id: str, payload) -> None:
        self.engine.import_slot(session_id, payload)

    def release_slot(self, session_id: str) -> None:
        self.engine.release_slot(session_id)


class SimulatedEngine:
    """Predictor/sampler-backed backend driven by (virtual) clock events.

    ``service_sampler(req) -> (ttfb_ms, total_ms)`` supplies each request's
    service time; per-request hints on the ``Request`` override it (the
    orchestrator passes predictor output, the §V scenarios pass
    ``LatencyModel`` draws). A request occupies its decode slot from
    admission until ``finish_at`` — queueing, class ordering, and premium
    reservation all come from the shared ``QoSScheduler``, not from any
    closed-form queue model.

    The backend also keeps a **serializable per-session state** that evolves
    deterministically with every admitted request (a small state vector plus
    the context position), speaking the same ``export_slot`` / ``import_slot``
    / ``release_slot`` protocol as the real engine — so the §V simulation arm
    migrates sessions through :mod:`repro.serving.state_transfer` under
    ``VirtualClock``, with real fingerprint verification and real abort paths.
    ``import_capacity`` bounds how many migrated-in sessions the backend will
    hold (None = unbounded); exhaustion raises — target admission denial.
    """

    exclusive_sessions = False   # per-request slots never collide per session

    STATE_DIM = 8

    def __init__(self, clock: Clock, *,
                 service_sampler: Optional[
                     Callable[[Request], Tuple[float, float]]] = None,
                 default_service_ms: float = 50.0,
                 import_capacity: Optional[int] = None):
        self.clock = clock
        self.service_sampler = service_sampler
        self.default_service_ms = default_service_ms
        self.import_capacity = import_capacity
        self._sessions: Dict[str, dict] = {}

    @property
    def needs_service_hints(self) -> bool:
        """Without a sampler the backend has no service-time source of its
        own — callers must pass predictor hints on each request."""
        return self.service_sampler is None

    # -- plane interface -------------------------------------------------
    def predicted_service_ms(self, req: Request) -> float:
        if req.hint_total_ms is not None:
            return req.hint_total_ms
        return self.default_service_ms

    def ensure_capacity(self, active_sessions) -> None:
        pass

    def _touch_state(self, req: Request) -> None:
        """Deterministic session-state evolution (crc32-seeded so two runs
        of the same trace produce byte-identical states and fingerprints)."""
        st = self._sessions.get(req.session_id)
        if st is None:
            st = {"cache": {"sim": np.zeros(self.STATE_DIM, np.float64)},
                  "position": 0, "last_token": 0}
            self._sessions[req.session_id] = st
        mix = (zlib.crc32(req.session_id.encode())
               + 31 * req.prompt_tokens + 7 * req.gen_tokens) % 1_000_003
        vec = st["cache"]["sim"]
        vec[1:] = vec[:-1]
        vec[0] = 0.5 * vec[0] + float(mix)
        st["position"] += req.prompt_tokens + req.gen_tokens
        st["last_token"] = int(mix % 50_257)

    def admit(self, req: Request, now: float) -> Admission:
        self._touch_state(req)
        if req.hint_total_ms is not None:
            ttfb = req.hint_ttfb_ms if req.hint_ttfb_ms is not None else 0.0
            total = req.hint_total_ms
        elif self.service_sampler is not None:
            ttfb, total = self.service_sampler(req)
        else:
            ttfb, total = 0.0, self.default_service_ms
        return Admission(ttfb_ms=ttfb, finish_at=now + total / 1e3)

    def decode_round(self, steps: Optional[int] = None) -> Dict[str, int]:
        return {}

    def release(self, session_id: str) -> None:
        # per-request slot release: session state persists across requests
        pass

    # -- migration data plane (engine slot protocol) ---------------------
    def has_slot(self, session_id: str) -> bool:
        return session_id in self._sessions

    def export_slot(self, session_id: str):
        st = self._sessions[session_id]
        return {"cache": {"sim": np.array(st["cache"]["sim"], copy=True)},
                "position": st["position"],
                "last_token": st["last_token"]}

    def import_slot(self, session_id: str, payload) -> None:
        if self.import_capacity is not None and \
                session_id not in self._sessions and \
                len(self._sessions) >= self.import_capacity:
            from repro.serving.state_transfer import AdmissionDenied
            raise AdmissionDenied(
                f"target admission denied: no free session slots for "
                f"{session_id}")
        self._sessions[session_id] = {
            "cache": {"sim": np.array(payload["cache"]["sim"], copy=True)},
            "position": int(payload["position"]),
            "last_token": int(payload["last_token"])}

    def release_slot(self, session_id: str) -> None:
        self._sessions.pop(session_id, None)


#: default fused-decode chunk sizes per QoS class: the chunk is the
#: preemption granularity — admission (and therefore premium TTFT) can only
#: happen between chunks, so the premium chunk stays small while best-effort
#: amortises dispatch overhead over longer runs
DEFAULT_DECODE_CHUNK = {"premium": 4, "assured": 8, "best-effort": 32}


class ServingPlane:
    """QoS-scheduled serving plane of ONE execution site."""

    def __init__(self, clock: Clock, backend, *, slots: int,
                 premium_reserved_frac: float = 0.25,
                 max_queue: Optional[int] = None,
                 site_id: str = "",
                 arrival_window: int = 128,
                 decode_chunk: Optional[Dict[str, int]] = None):
        self.clock = clock
        self.backend = backend
        self.site_id = site_id
        self.decode_chunk = dict(DEFAULT_DECODE_CHUNK)
        if decode_chunk:
            self.decode_chunk.update(decode_chunk)
        self.scheduler = QoSScheduler(
            clock, slots=slots, premium_reserved_frac=premium_reserved_frac)
        #: None = unbounded queue; N = loss system once running+queued
        #: exceeds slots+N (admission control for the §V scenarios)
        self.max_queue = max_queue
        self._events: List[Tuple[float, int, Request]] = []   # finish heap
        self._seq = itertools.count()
        self._tokens: Dict[str, int] = {}          # request_id -> generated
        self._tok_ids: Dict[str, List[int]] = {}   # real backends: token ids
        self._active_sessions: set = set()         # sessions with a running req
        self._by_request: Dict[str, Request] = {}
        self._done: Dict[str, PlaneResult] = {}
        self._outbox: List[PlaneResult] = []
        self._arrivals: Deque[float] = collections.deque(maxlen=arrival_window)
        self._req_ids = itertools.count()
        #: plane-level migration failure injection (tests): export-side hooks
        #: fire when this plane is the SOURCE, import-side when it is the
        #: TARGET (see state_transfer.TransferInjections)
        self.migration_inject = None
        #: supervisor readiness gate: a draining/dead site stops admitting —
        #: submits reject (accounted) while in-flight work keeps streaming
        self.admitting = True

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, *, session_id: str, klass: str, prompt_tokens: int,
               gen_tokens: int, t_max_ms: float,
               request_id: Optional[str] = None,
               hint_ttfb_ms: Optional[float] = None,
               hint_total_ms: Optional[float] = None,
               prompt=None, resume: bool = False,
               adapter_id: str = "") -> Optional[Request]:
        """Enqueue one request; returns None when admission control rejects
        it (bounded-queue planes, or a plane gated closed by its
        supervisor), after accounting the rejection."""
        if not self.admitting:
            self.scheduler.stats.rejected += 1
            return None
        now = self.clock.now()
        self._arrivals.append(now)
        if self.max_queue is not None and \
                (len(self.scheduler.running) + self.scheduler.queue_depth()
                 >= self.scheduler.slots + self.max_queue):
            self.scheduler.stats.rejected += 1
            return None
        req = Request(
            request_id=request_id or f"{self.site_id}/req-{next(self._req_ids)}",
            session_id=session_id, klass=klass,
            prompt_tokens=prompt_tokens, gen_tokens=gen_tokens,
            t_max_ms=t_max_ms, hint_ttfb_ms=hint_ttfb_ms,
            hint_total_ms=hint_total_ms, prompt=prompt, resume=resume,
            adapter_id=adapter_id)
        self._by_request[req.request_id] = req
        self.scheduler.submit(req)
        self._admit()
        return req

    # ------------------------------------------------------------------
    # internal machinery
    # ------------------------------------------------------------------
    def _skip(self, req: Request) -> bool:
        """Engine backends key slots by session: a session with a plane
        request already in flight must wait for it (per-slot cache
        positions). Slots held OUTSIDE the plane (e.g. migrated-in state)
        do not block — the backend reclaims them at admission."""
        return self.backend.exclusive_sessions and \
            req.session_id in self._active_sessions

    def _fast_fail(self, req: Request) -> None:
        self._finish(req, ttfb_ms=0.0, completed=False,
                     failed=FailureCause.DEADLINE_EXPIRY)

    def _admit(self) -> None:
        batch = self.scheduler.next_batch(
            predicted_service_ms=self.backend.predicted_service_ms,
            skip=self._skip, on_fast_fail=self._fast_fail)
        for req in batch:
            # the admitting request's own session must never be the reclaim
            # victim — a resume=True request's parked state is exactly what
            # it is about to continue
            self.backend.ensure_capacity(
                self._active_sessions | {req.session_id})
            try:
                adm = self.backend.admit(req, self.clock.now())
            except Exception as e:
                # the request is already in scheduler.running — a backend
                # refusal (oversized prompt, engine failure) must free that
                # slot and surface as a failed result, never wedge the site
                self.scheduler.detach(req.request_id)
                cause = (FailureCause.NO_FEASIBLE_BINDING
                         if isinstance(e, ValueError)   # infeasible request
                         else FailureCause.COMPUTE_SCARCITY)
                self._finish(req, ttfb_ms=0.0, completed=False, failed=cause)
                continue
            self._active_sessions.add(req.session_id)
            req.hint_ttfb_ms = adm.ttfb_ms            # measured/known TTFB
            if adm.finish_at is not None:
                # event-driven backend: the whole generation completes at
                # finish_at, so the token budget is accounted up front
                self._tokens[req.request_id] = req.gen_tokens
                heapq.heappush(self._events,
                               (adm.finish_at, next(self._seq), req))
            elif adm.resumed:
                # no prefill ran: generation continues from the bound
                # state at the next decode round
                self._tokens[req.request_id] = 0
                self._tok_ids[req.request_id] = []
            else:
                self._tokens[req.request_id] = 1      # prefill's first token
                if adm.first_token is not None:
                    self._tok_ids[req.request_id] = [adm.first_token]

    def _finish(self, req: Request, *, ttfb_ms: float, completed: bool,
                failed: Optional[FailureCause] = None) -> None:
        now = self.clock.now()
        latency_ms = (now - req.submitted_at) * 1e3
        started = req.started_at if req.started_at is not None else now
        wait_ms = (started - req.submitted_at) * 1e3
        res = PlaneResult(
            request_id=req.request_id, session_id=req.session_id,
            klass=req.klass, ttfb_ms=ttfb_ms, latency_ms=latency_ms,
            queue_wait_ms=wait_ms,
            tokens=self._tokens.pop(req.request_id, 0),
            completed=completed and failed is None, failed=failed,
            token_ids=self._tok_ids.pop(req.request_id, None),
            prompt_tokens=req.prompt_tokens)
        self._done[req.request_id] = res
        self._outbox.append(res)
        self._by_request.pop(req.request_id, None)

    def _complete(self, req: Request) -> None:
        self.scheduler.complete(req.request_id)
        self.backend.release(req.session_id)
        self._active_sessions.discard(req.session_id)
        latency_ms = (self.clock.now() - req.submitted_at) * 1e3
        self._finish(req, ttfb_ms=req.hint_ttfb_ms or 0.0,
                     completed=latency_ms <= req.t_max_ms)
        self._admit()               # freed slot: admit from the queue

    def _chunk_steps(self) -> int:
        """Fused-decode chunk size for the next round: bounded by (a) the
        smallest remaining token budget among running requests — no slot
        ever overshoots its request, so per-request accounting stays exact —
        and (b) the chunk cap of the highest QoS class present (running OR
        queued: a queued premium request must not wait out a long
        best-effort chunk for its admission slot). The bound is then rounded
        DOWN to a power of two so the engine compiles O(log max_chunk) fused
        scans total (request tails would otherwise trace a fresh scan for
        every distinct remaining count)."""
        remaining = [
            req.gen_tokens - self._tokens.get(req.request_id, 0)
            for req in self.scheduler.running.values()]
        if not remaining:
            return 1
        cap = max(self.decode_chunk.values())
        classes = {r.klass for r in self.scheduler.running.values()}
        classes |= {k for k, d in self.scheduler.queues.items() if d}
        for k in classes:
            cap = min(cap, self.decode_chunk.get(k, 1))
        bound = max(1, min(min(remaining), cap))
        return 1 << (bound.bit_length() - 1)     # pow2 floor

    def _round(self) -> bool:
        """One continuous-batching decode chunk (real backends): K fused
        decode steps in one dispatch, K picked per QoS mix. Returns False
        when the round made no progress (nothing active, or a simulated
        backend whose progress is event-driven)."""
        if not self.scheduler.running:
            return False
        steps = self._chunk_steps()
        out = self.backend.decode_round(steps=steps)
        if not out:
            return False
        finished = []
        for req in list(self.scheduler.running.values()):
            if req.session_id in out:
                block = out[req.session_id]
                self._tokens[req.request_id] = \
                    self._tokens.get(req.request_id, 0) + len(block)
                if req.request_id in self._tok_ids:
                    self._tok_ids[req.request_id].extend(block)
                if self._tokens[req.request_id] >= req.gen_tokens:
                    finished.append(req)
        for req in finished:
            self._complete(req)
        return True

    # ------------------------------------------------------------------
    # make-before-break handover (migration data plane)
    # ------------------------------------------------------------------
    def detach_session(self, session_id: str) -> SessionHandoff:
        """Detach a session's in-flight work (running request + token
        accounting AND its queued requests) for handover to another plane.
        Backend slot state is NOT touched — the transfer path exports/
        releases it under two-phase ordering. The freed scheduler slot is
        immediately available to other queued work."""
        queued = self.scheduler.take_queued(session_id)
        for r in queued:
            self._by_request.pop(r.request_id, None)
        req = next((r for r in self.scheduler.running.values()
                    if r.session_id == session_id), None)
        if req is None:
            return SessionHandoff(session_id, None, queued=queued)
        self.scheduler.detach(req.request_id)
        self._active_sessions.discard(session_id)
        self._by_request.pop(req.request_id, None)
        finish_at = None
        for i, (t, _seq, r) in enumerate(self._events):
            if r.request_id == req.request_id:
                finish_at = t
                self._events[i] = self._events[-1]
                self._events.pop()
                heapq.heapify(self._events)
                break
        return SessionHandoff(
            session_id, req,
            tokens=self._tokens.pop(req.request_id, 0),
            token_ids=self._tok_ids.pop(req.request_id, None),
            finish_at=finish_at, queued=queued)

    def attach_session(self, handoff: SessionHandoff) -> None:
        """Install work handed over from another plane: the running request
        occupies a slot here and keeps streaming from where the source left
        off, queued requests join this plane's class queues with their
        original submit times (the QoS occupancy follows the session)."""
        req = handoff.request
        if req is not None:
            self.scheduler.attach(req)
            self._active_sessions.add(req.session_id)
            self._by_request[req.request_id] = req
            self._tokens[req.request_id] = handoff.tokens
            if handoff.token_ids is not None:
                self._tok_ids[req.request_id] = handoff.token_ids
            if handoff.finish_at is not None:
                heapq.heappush(self._events,
                               (handoff.finish_at, next(self._seq), req))
        for r in handoff.queued:
            self._by_request[r.request_id] = r
        self.scheduler.put_queued(handoff.queued)
        if handoff.queued:
            self._admit()

    def fail_all(self, cause: FailureCause) -> int:
        """Crash semantics: every running AND queued request fails with
        ``cause`` through the normal served-and-failed accounting (results
        land in the outbox so telemetry attributes them), pending completion
        events are dropped, and the plane stops admitting. Returns the
        number of requests failed. The backend is NOT consulted — a crashed
        engine cannot be asked to release anything."""
        self.admitting = False
        n = 0
        for req in list(self.scheduler.running.values()):
            self.scheduler.detach(req.request_id)
            self._active_sessions.discard(req.session_id)
            self._finish(req, ttfb_ms=req.hint_ttfb_ms or 0.0,
                         completed=False, failed=cause)
            n += 1
        for q in self.scheduler.queues.values():
            while q:
                req = q.popleft()
                self._finish(req, ttfb_ms=0.0, completed=False, failed=cause)
                n += 1
        self._events.clear()
        return n

    # ------------------------------------------------------------------
    # driving
    # ------------------------------------------------------------------
    def run_until(self, t: float) -> None:
        """Process completion events up to absolute clock time ``t``;
        advances a virtual clock through each event in order."""
        while self._events and self._events[0][0] <= t:
            finish_at, _, req = heapq.heappop(self._events)
            now = self.clock.now()
            if finish_at > now:
                self.clock.sleep(finish_at - now)
            self._complete(req)
        now = self.clock.now()
        if t > now and isinstance(self.clock, VirtualClock):
            self.clock.advance(t - now)
        self._admit()

    def drain(self, *, max_rounds: int = 1_000_000) -> None:
        """Run until every queued/running request has completed."""
        rounds = 0
        while self.scheduler.running or self.scheduler.queue_depth():
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError("serving plane failed to drain")
            if self._events:
                finish_at, _, req = heapq.heappop(self._events)
                now = self.clock.now()
                if finish_at > now:
                    self.clock.sleep(finish_at - now)
                self._complete(req)
                continue
            if not self._round():
                # nothing active and no events: only queued work remains —
                # admission must be blocked; admit or bail
                before = self.scheduler.queue_depth()
                self._admit()
                if self.scheduler.queue_depth() == before and \
                        not self.scheduler.running:
                    break

    def serve(self, *, session_id: str, klass: str, prompt_tokens: int,
              gen_tokens: int, t_max_ms: float,
              request_id: Optional[str] = None,
              hint_ttfb_ms: Optional[float] = None,
              hint_total_ms: Optional[float] = None,
              prompt=None, resume: bool = False,
              adapter_id: str = "") -> PlaneResult:
        """Unary convenience: submit and drive the plane until THIS request
        completes (other in-flight sessions make progress too — decode
        rounds are shared)."""
        req = self.submit(
            session_id=session_id, klass=klass, prompt_tokens=prompt_tokens,
            gen_tokens=gen_tokens, t_max_ms=t_max_ms, request_id=request_id,
            hint_ttfb_ms=hint_ttfb_ms, hint_total_ms=hint_total_ms,
            prompt=prompt, resume=resume, adapter_id=adapter_id)
        if req is None:
            return PlaneResult(
                request_id="rejected", session_id=session_id, klass=klass,
                ttfb_ms=0.0, latency_ms=0.0, queue_wait_ms=0.0, tokens=0,
                completed=False, failed=FailureCause.COMPUTE_SCARCITY)
        guard = 0
        while req.request_id not in self._done:
            guard += 1
            if guard > 10_000_000:
                raise RuntimeError("request failed to complete")
            if self._events:
                finish_at, _, r = heapq.heappop(self._events)
                now = self.clock.now()
                if finish_at > now:
                    self.clock.sleep(finish_at - now)
                self._complete(r)
            elif not self._round():
                self._admit()
                if req.request_id not in self._done and \
                        req.request_id not in self.scheduler.running and \
                        not self._events:
                    # neither running nor done after an admission pass —
                    # fast-failed, or admission is blocked for good
                    break
        res = self._done.get(req.request_id)
        if res is None:
            raise RuntimeError(
                f"request {req.request_id} cannot progress "
                "(engine slot held outside the plane?)")
        return res

    # ------------------------------------------------------------------
    # results + telemetry surface
    # ------------------------------------------------------------------
    def pop_results(self) -> List[PlaneResult]:
        """Drain completed results (the orchestrator records telemetry and
        metering from these exactly once)."""
        out, self._outbox = self._outbox, []
        return out

    def result(self, request_id: str) -> Optional[PlaneResult]:
        return self._done.get(request_id)

    def load(self) -> PlaneLoad:
        """Measured congestion ξ for the analytics loop. Also drives the
        backend's idle-TTL tick (parked → hibernated), so tiering policy
        advances at heartbeat cadence without a separate timer."""
        tick = getattr(self.backend, "tick", None)
        if callable(tick):
            tick()
        occ_fn = getattr(self.backend, "occupancy", None)
        occ = occ_fn() if callable(occ_fn) else {}
        slots = max(self.scheduler.slots, 1)
        rate = 0.0
        if len(self._arrivals) >= 2:
            span = self.clock.now() - self._arrivals[0]
            if span > 0:
                rate = len(self._arrivals) / span
        return PlaneLoad(
            queue_depth=self.scheduler.queue_depth() / slots,
            arrival_rate=rate,
            running=len(self.scheduler.running),
            slots=slots,
            utilization=len(self.scheduler.running) / slots,
            **occ)
