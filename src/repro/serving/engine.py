"""Slot-based continuous-batching inference engine.

One engine instance = one execution anchor's serving plane for one model:
a fixed decode batch of ``slots`` sequences sharing jitted prefill /
decode functions. Sessions join/leave slots independently (per-slot
positions in the cache make lockstep unnecessary). The engine is the
``v_cmp`` substrate AIS compute leases reserve against, and its
``export_slot``/``import_slot`` are the state-transfer primitive behind
make-before-break migration.

Hot-path disciplines (the per-token costs that separate a toy loop from a
serving engine):

* **Fused multi-step decode** — ``decode_round(steps=K)`` runs K decode
  steps inside ONE jitted ``lax.scan`` with on-device greedy sampling and
  an on-device active-slot mask: one dispatch and one device→host transfer
  per K tokens instead of per token.
* **Bucketed prefill** — prompts are right-padded to power-of-two buckets
  with the true length threaded through ``LM.prefill`` as a traced scalar,
  so the engine compiles O(log max_len) prefill variants instead of one
  per distinct prompt length (``prefill_compiles`` exposes the counter).
* **Donated, index-addressed slot state** — slot insert (admit / migrate
  in) and the decode cache update run under ``jax.jit(...,
  donate_argnums=...)`` with per-slot ``dynamic_update_slice`` writes, so
  admitting or exporting a session no longer materialises a second full
  cache.

On the CPU container this runs the tiny models for examples/tests; on a pod
the same code jit-compiles under the production mesh with the decode plan's
shardings (see repro.launch.serve).
"""

from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import LM

#: smallest prefill bucket — below this the compile is cheap enough that
#: further splitting buys nothing
_MIN_BUCKET = 16


def prefill_buckets(max_len: int) -> List[int]:
    """Power-of-two padded prompt lengths, capped at ``max_len``.

    len(buckets) <= ceil(log2(max_len)): the compile-count ceiling the
    engine guarantees over any prompt-length mix.
    """
    out: List[int] = []
    b = _MIN_BUCKET
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


@dataclass
class SlotState:
    session_id: str
    position: int
    tokens_generated: int = 0
    last_token: int = 0


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params=None, *, slots: int = 8,
                 max_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.lm = LM(cfg)
        self.slots = slots
        self.max_len = max_len
        if params is None:
            params = self.lm.init(jax.random.key(seed))
        self.params = params
        self.cache = self.lm.init_cache(slots, max_len)
        self._slot_map: Dict[str, int] = {}
        self._slots: list[Optional[SlotState]] = [None] * slots
        self.buckets = prefill_buckets(max_len)
        self._compiled_buckets: set = set()
        self._prefill = jax.jit(
            lambda p, b: self.lm.prefill(p, b, self.max_len))
        # K-step fused decode: cache is DONATED — the scan updates it in
        # place instead of double-buffering the whole KV cache
        self._decode_fused = jax.jit(self._fused_impl, static_argnums=(4,),
                                     donate_argnums=(1,))
        # slot insert: donate the full cache so admit/import is a per-slot
        # dynamic_update, not a full-cache copy
        self._slot_write = jax.jit(self._slot_write_impl, donate_argnums=(0,))
        self._slot_read = jax.jit(self._slot_read_impl)

    # ------------------------------------------------------------------
    def free_slots(self) -> int:
        return sum(1 for s in self._slots if s is None)

    def has_slot(self, session_id: str) -> bool:
        return session_id in self._slot_map

    def position_of(self, session_id: str) -> int:
        """Current cache position (context length) of one session's slot —
        the authoritative payload size for migration."""
        meta = self._slots[self._slot_map[session_id]]
        return meta.position

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill shapes traced so far (== jit cache entries:
        the padded width is the only shape that varies across prompts)."""
        return len(self._compiled_buckets)

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_len

    def _alloc(self, session_id: str) -> int:
        for i, s in enumerate(self._slots):
            if s is None:
                self._slot_map[session_id] = i
                return i
        raise RuntimeError("no free decode slots (lease accounting bug)")

    # ------------------------------------------------------------------
    def _batch_axis(self, path) -> int:
        """Slot/batch axis of a cache leaf: stacked families carry layers
        first ([L, b, ...]); hybrid leaves and 'pos' are slot-first."""
        keys = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
        if "pos" in keys or self.cfg.family == "hybrid":
            return 0
        return 1 if any(str(k) in ("k", "v", "conv", "ssm", "cross_k",
                                   "cross_v") for k in keys) else 0

    def _slot_write_impl(self, cache, cache1, idx):
        """Insert a batch-1 cache into slot ``idx`` (donated, traced idx)."""
        def ins(path, full, one):
            ax = self._batch_axis(path)
            return jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), idx, axis=ax)

        return jax.tree_util.tree_map_with_path(ins, cache, cache1)

    def _slot_read_impl(self, cache, idx):
        """Extract the batch-1 state of slot ``idx`` (no donation — the
        source keeps serving while migration is in flight)."""
        def ext(path, full):
            ax = self._batch_axis(path)
            return jax.lax.dynamic_slice_in_dim(full, idx, 1, axis=ax)

        return jax.tree_util.tree_map_with_path(ext, cache)

    def _write_slot(self, idx: int, cache1):
        """Insert a batch-1 cache into slot ``idx`` of the engine cache."""
        self.cache = self._slot_write(self.cache, cache1, jnp.int32(idx))

    def export_slot(self, session_id: str):
        """Extract this session's state (the migration payload)."""
        idx = self._slot_map[session_id]
        state = self._slot_read(self.cache, jnp.int32(idx))
        meta = self._slots[idx]
        return {"cache": state, "position": meta.position,
                "last_token": meta.last_token}

    def import_slot(self, session_id: str, payload) -> None:
        """Install a migrated session's state into a free slot. Raises
        AdmissionDenied when the target has no free slot — the migration
        abort cause (COMPUTE_SCARCITY), distinct from the lease-accounting
        bug the prefill path's exhaustion signals."""
        if self.free_slots() == 0:
            from repro.serving.state_transfer import AdmissionDenied
            raise AdmissionDenied(
                f"target admission denied: no free decode slots for "
                f"{session_id}")
        idx = self._alloc(session_id)
        self._write_slot(idx, payload["cache"])
        self._slots[idx] = SlotState(session_id, payload["position"],
                                     last_token=payload["last_token"])

    def release_slot(self, session_id: str) -> None:
        idx = self._slot_map.pop(session_id, None)
        if idx is not None:
            self._slots[idx] = None

    # ------------------------------------------------------------------
    def prefill_session(self, session_id: str, prompt: np.ndarray) -> dict:
        """Admit a session: run prefill, install the cache, return TTFT.

        The prompt is right-padded to its power-of-two bucket with the true
        length passed as a traced scalar — the whole mix of prompt lengths
        compiles at most ``len(self.buckets)`` prefill variants.
        """
        t0 = time.perf_counter()
        n = len(prompt)
        if n > self.max_len:
            # refuse rather than silently truncate: a truncated prefill
            # would condition generation on a clipped prefix while
            # position_of()/migration payload sizing report the full length
            raise ValueError(
                f"prompt of {n} tokens exceeds engine max_len "
                f"{self.max_len} for {session_id}")
        width = self._bucket(n)
        padded = np.zeros(width, np.int32)
        padded[:n] = prompt
        self._compiled_buckets.add(width)
        batch = {"tokens": jnp.asarray(padded[None, :], jnp.int32),
                 "length": jnp.int32(n)}
        logits, cache1 = self._prefill(self.params, batch)
        tok = int(jnp.argmax(logits[0]))
        idx = self._alloc(session_id)
        self._write_slot(idx, cache1)
        self._slots[idx] = SlotState(session_id, position=n,
                                     tokens_generated=1, last_token=tok)
        return {"first_token": tok,
                "ttfb_ms": (time.perf_counter() - t0) * 1e3}

    # ------------------------------------------------------------------
    def _fused_impl(self, params, cache, last, active, steps: int):
        """K decode steps in one jitted scan. ``last``: [slots] int32 token
        feedback; ``active``: [slots] bool — inactive slots keep feeding
        their (zero) token so a fused chunk is bit-identical to K sequential
        single-step rounds regardless of who shares the batch.
        Returns (cache, token block [slots, K])."""
        def step(carry, _):
            c, fed = carry
            logits, c = self.lm.decode_step(params, c, fed[:, None])
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            fed = jnp.where(active, nxt, fed)
            return (c, fed), fed

        (cache, _), toks = jax.lax.scan(step, (cache, last), None,
                                        length=steps)
        return cache, jnp.moveaxis(toks, 0, 1)          # [slots, K]

    def decode_round(self, steps: Optional[int] = None
                     ) -> Dict[str, Union[int, List[int]]]:
        """Continuous-batching decode for every active slot.

        ``steps=None`` — legacy single-step form: {session: token}.
        ``steps=K``    — fused K-step chunk: {session: [token, ...] * K},
        produced by ONE dispatch and ONE device→host transfer.
        """
        if not self._slot_map:
            return {}
        k = 1 if steps is None else max(1, int(steps))
        last = np.zeros(self.slots, np.int32)
        active = np.zeros(self.slots, bool)
        for i, s in enumerate(self._slots):
            if s is not None:
                last[i] = s.last_token
                active[i] = True
        self.cache, block = self._decode_fused(
            self.params, self.cache, jnp.asarray(last),
            jnp.asarray(active), k)
        block = np.asarray(block)                        # [slots, K]
        out: Dict[str, Union[int, List[int]]] = {}
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            s.last_token = int(block[i, -1])
            s.position += k
            s.tokens_generated += k
            out[s.session_id] = (int(block[i, 0]) if steps is None
                                 else [int(t) for t in block[i]])
        return out

    # ------------------------------------------------------------------
    def serve(self, session_id: str, prompt_tokens: int, gen_tokens: int,
              *, prompt: Optional[np.ndarray] = None,
              chunk: int = 16) -> dict:
        """Unary convenience: prefill + chunked decode for one session.

        Synthetic prompts are crc32-seeded (NOT ``hash()``, which varies
        per process under PYTHONHASHSEED and would break reproducible
        traces and cross-process fingerprint checks)."""
        rng = np.random.default_rng(
            zlib.crc32(session_id.encode()) % 2**31)
        if prompt is None:
            prompt = rng.integers(0, self.cfg.vocab_size,
                                  size=prompt_tokens).astype(np.int32)
        t0 = time.perf_counter()
        pre = self.prefill_session(session_id, prompt)
        toks = [pre["first_token"]]
        remaining = gen_tokens - 1
        while remaining > 0:
            # pow2 chunk schedule: O(log chunk) compiled scan variants
            k = min(chunk, 1 << (remaining.bit_length() - 1))
            out = self.decode_round(steps=k)
            toks.extend(out[session_id])
            remaining -= k
        self.release_slot(session_id)
        total_ms = (time.perf_counter() - t0) * 1e3
        return {"tokens": toks, "ttfb_ms": pre["ttfb_ms"],
                "latency_ms": total_ms}
