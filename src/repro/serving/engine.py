"""Slot-based continuous-batching inference engine.

One engine instance = one execution anchor's serving plane for one model:
a fixed decode batch of ``slots`` sequences sharing jitted prefill /
decode_step functions. Sessions join/leave slots independently (per-slot
positions in the cache make lockstep unnecessary). The engine is the
``v_cmp`` substrate AIS compute leases reserve against, and its
``export_slot``/``import_slot`` are the state-transfer primitive behind
make-before-break migration.

On the CPU container this runs the tiny models for examples/tests; on a pod
the same code jit-compiles under the production mesh with the decode plan's
shardings (see repro.launch.serve).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import LM


@dataclass
class SlotState:
    session_id: str
    position: int
    tokens_generated: int = 0
    last_token: int = 0


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params=None, *, slots: int = 8,
                 max_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.lm = LM(cfg)
        self.slots = slots
        self.max_len = max_len
        if params is None:
            params = self.lm.init(jax.random.key(seed))
        self.params = params
        self.cache = self.lm.init_cache(slots, max_len)
        self._slot_map: Dict[str, int] = {}
        self._slots: list[Optional[SlotState]] = [None] * slots
        self._prefill = jax.jit(
            lambda p, b: self.lm.prefill(p, b, self.max_len))
        self._decode = jax.jit(self.lm.decode_step)
        self._active_mask = np.zeros(slots, bool)

    # ------------------------------------------------------------------
    def free_slots(self) -> int:
        return sum(1 for s in self._slots if s is None)

    def has_slot(self, session_id: str) -> bool:
        return session_id in self._slot_map

    def position_of(self, session_id: str) -> int:
        """Current cache position (context length) of one session's slot —
        the authoritative payload size for migration."""
        meta = self._slots[self._slot_map[session_id]]
        return meta.position

    def _alloc(self, session_id: str) -> int:
        for i, s in enumerate(self._slots):
            if s is None:
                self._slot_map[session_id] = i
                return i
        raise RuntimeError("no free decode slots (lease accounting bug)")

    # ------------------------------------------------------------------
    def _batch_axis(self, path) -> int:
        """Slot/batch axis of a cache leaf: stacked families carry layers
        first ([L, b, ...]); hybrid leaves and 'pos' are slot-first."""
        keys = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
        if "pos" in keys or self.cfg.family == "hybrid":
            return 0
        return 1 if any(str(k) in ("k", "v", "conv", "ssm", "cross_k",
                                   "cross_v") for k in keys) else 0

    def _write_slot(self, idx: int, cache1):
        """Insert a batch-1 cache into slot ``idx`` of the engine cache."""
        def ins(path, full, one):
            ax = self._batch_axis(path)
            one_row = jax.lax.index_in_dim(one, 0, axis=ax, keepdims=False)
            if ax == 0:
                return full.at[idx].set(one_row)
            return full.at[:, idx].set(one_row)

        self.cache = jax.tree_util.tree_map_with_path(ins, self.cache, cache1)

    def export_slot(self, session_id: str):
        """Extract this session's state (the migration payload)."""
        idx = self._slot_map[session_id]

        def ext(path, full):
            ax = self._batch_axis(path)
            return jax.lax.slice_in_dim(full, idx, idx + 1, axis=ax)

        state = jax.tree_util.tree_map_with_path(ext, self.cache)
        meta = self._slots[idx]
        return {"cache": state, "position": meta.position,
                "last_token": meta.last_token}

    def import_slot(self, session_id: str, payload) -> None:
        """Install a migrated session's state into a free slot. Raises
        AdmissionDenied when the target has no free slot — the migration
        abort cause (COMPUTE_SCARCITY), distinct from the lease-accounting
        bug the prefill path's exhaustion signals."""
        if self.free_slots() == 0:
            from repro.serving.state_transfer import AdmissionDenied
            raise AdmissionDenied(
                f"target admission denied: no free decode slots for "
                f"{session_id}")
        idx = self._alloc(session_id)
        self._write_slot(idx, payload["cache"])
        self._slots[idx] = SlotState(session_id, payload["position"],
                                     last_token=payload["last_token"])

    def release_slot(self, session_id: str) -> None:
        idx = self._slot_map.pop(session_id, None)
        if idx is not None:
            self._slots[idx] = None

    # ------------------------------------------------------------------
    def prefill_session(self, session_id: str, prompt: np.ndarray) -> dict:
        """Admit a session: run prefill, install the cache, return TTFT."""
        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(prompt[None, :], jnp.int32)}
        logits, cache1 = self._prefill(self.params, batch)
        tok = int(jnp.argmax(logits[0]))
        idx = self._alloc(session_id)
        self._write_slot(idx, cache1)
        self._slots[idx] = SlotState(session_id, position=len(prompt),
                                     tokens_generated=1, last_token=tok)
        return {"first_token": tok,
                "ttfb_ms": (time.perf_counter() - t0) * 1e3}

    def decode_round(self) -> Dict[str, int]:
        """One continuous-batching decode step for every active slot."""
        if not self._slot_map:
            return {}
        toks = np.zeros((self.slots, 1), np.int32)
        for i, s in enumerate(self._slots):
            if s is not None:
                toks[i, 0] = s.last_token
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        out = {}
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            s.last_token = int(nxt[i])
            s.position += 1
            s.tokens_generated += 1
            out[s.session_id] = s.last_token
        return out

    # ------------------------------------------------------------------
    def serve(self, session_id: str, prompt_tokens: int, gen_tokens: int,
              *, prompt: Optional[np.ndarray] = None) -> dict:
        """Unary convenience: prefill + N decode rounds for one session."""
        rng = np.random.default_rng(hash(session_id) % 2**31)
        if prompt is None:
            prompt = rng.integers(0, self.cfg.vocab_size,
                                  size=prompt_tokens).astype(np.int32)
        t0 = time.perf_counter()
        pre = self.prefill_session(session_id, prompt)
        toks = [pre["first_token"]]
        for _ in range(gen_tokens - 1):
            out = self.decode_round()
            toks.append(out[session_id])
        self.release_slot(session_id)
        total_ms = (time.perf_counter() - t0) * 1e3
        return {"tokens": toks, "ttfb_ms": pre["ttfb_ms"],
                "latency_ms": total_ms}
