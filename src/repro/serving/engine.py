"""Slot-based continuous-batching inference engine.

One engine instance = one execution anchor's serving plane for one model:
a fixed decode batch of ``slots`` sequences sharing jitted prefill /
decode functions. Sessions join/leave slots independently (per-slot
positions in the cache make lockstep unnecessary). The engine is the
``v_cmp`` substrate AIS compute leases reserve against, and its
``export_slot``/``import_slot`` are the state-transfer primitive behind
make-before-break migration.

Hot-path disciplines (the per-token costs that separate a toy loop from a
serving engine):

* **Fused multi-step decode** — ``decode_round(steps=K)`` runs K decode
  steps inside ONE jitted ``lax.scan`` with on-device greedy sampling and
  an on-device active-slot mask: one dispatch and one device→host transfer
  per K tokens instead of per token.
* **Bucketed prefill** — prompts are right-padded to power-of-two buckets
  with the true length threaded through ``LM.prefill`` as a traced scalar,
  so the engine compiles O(log max_len) prefill variants instead of one
  per distinct prompt length (``prefill_compiles`` exposes the counter).
* **Donated, index-addressed slot state** — slot insert (admit / migrate
  in) and the decode cache update run under ``jax.jit(...,
  donate_argnums=...)`` with per-slot ``dynamic_update_slice`` writes, so
  admitting or exporting a session no longer materialises a second full
  cache.

On the CPU container this runs the tiny models for examples/tests; on a pod
the same code jit-compiles under the production mesh with the decode plan's
shardings (see repro.launch.serve).
"""

from __future__ import annotations

import itertools
import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import LM
from repro.models import kvcache as KV

#: smallest prefill bucket — below this the compile is cheap enough that
#: further splitting buys nothing
_MIN_BUCKET = 16


class PagePoolExhausted(RuntimeError):
    """The paged engine has no free KV pages for an allocation. This is the
    explicit admission signal the paged layout buys: sessions no longer
    reserve ``max_len`` up front, so running out of MEMORY (pages) is
    distinct from running out of decode SLOTS — the serving plane maps it
    to COMPUTE_SCARCITY, and pressure-driven reclamation (hibernate the
    coldest parked sessions) is supposed to keep it from firing at all."""


def prefill_buckets(max_len: int) -> List[int]:
    """Power-of-two padded prompt lengths, capped at ``max_len``.

    len(buckets) <= ceil(log2(max_len)): the compile-count ceiling the
    engine guarantees over any prompt-length mix.
    """
    out: List[int] = []
    b = _MIN_BUCKET
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


@dataclass
class SlotState:
    session_id: str
    position: int
    tokens_generated: int = 0
    last_token: int = 0
    #: tenant adapter bound to this session ("" = base model). Resolved
    #: to an int32 table index per decode round; travels with the
    #: export payload so migration/hibernation keep the binding.
    adapter_id: str = ""
    #: parked = bound-but-idle: the session keeps its slot (and pages) but
    #: rides decode rounds with active=False, so its state never advances —
    #: the cheap-resume tier between resident and hibernated
    parked: bool = False
    #: monotone use tick (engine-local LRU clock, not wall time)
    last_used: int = 0
    #: page ids owned by this slot, in block-table order (paged engines)
    pages: List[int] = field(default_factory=list)


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params=None, *, slots: int = 8,
                 max_len: int = 512, seed: int = 0,
                 paged: bool = False,
                 page_size: int = KV.DEFAULT_PAGE_SIZE,
                 num_pages: Optional[int] = None,
                 hibernation=None, clock=None, adapters=None):
        """``paged=True`` selects the block-table paged KV layout for
        families that support it (full-attention stacked KV — see
        ``kvcache.supports_paging``); other families silently keep the dense
        slot layout (their state is O(window)/O(1) and gains nothing from
        paging) but still park and hibernate. ``num_pages`` bounds device
        KV memory (default: enough for every slot at max_len, plus the
        scratch page — no worse than dense). ``hibernation`` is a
        :class:`~repro.serving.hibernation.HibernationStore` (or ``True``
        for a private unbounded one) enabling the host-memory tier.
        ``clock`` (any object with ``now()``) timestamps hibernation
        records so store-side TTL/LRU ordering sees real ages.
        ``adapters`` is an :class:`~repro.adapters.runtime.AdapterRuntime`
        (or ``True`` for a default-sized one) enabling per-session LoRA
        multiplexing over this engine's base model."""
        self.cfg = cfg
        self.lm = LM(cfg)
        self.slots = slots
        self.max_len = max_len
        if params is None:
            params = self.lm.init(jax.random.key(seed))
        self.params = params
        self.paged = bool(paged) and KV.supports_paging(cfg)
        if hibernation is True:
            from repro.serving.hibernation import HibernationStore
            hibernation = HibernationStore()
        if hibernation is False:                   # bool flag, not a store
            hibernation = None
        self.hibernation = hibernation
        self.clock = clock
        #: canonical exports: linear stacked-KV buffers zero their garbage
        #: tail (rows at index >= position: prefill bucket padding, stale
        #: rows of re-used slots), so the SAME logical state always
        #: fingerprints identically — across dense and paged engines, and
        #: across hibernate/resume round trips
        self._canonical = cfg.family in ("dense", "moe") \
            and not cfg.sliding_window
        if self.paged:
            self.page_size = KV.page_len(cfg, max_len, page_size)
            self.pages_per_slot = KV.pages_per_slot(max_len, self.page_size)
            full = 1 + slots * self.pages_per_slot      # incl. scratch page
            self.num_pages = full if num_pages is None \
                else max(2, int(num_pages))
            self.cache = KV.init_paged_cache(cfg, slots, max_len,
                                             self.num_pages, self.page_size)
            # free list excludes page 0 (the shared scratch/null page);
            # popped from the tail so allocation order is ascending
            self._free_page_list: List[int] = \
                list(range(self.num_pages - 1, 0, -1))
            self._block_host = np.zeros((slots, self.pages_per_slot),
                                        np.int32)
            self._paged_install = jax.jit(self._paged_install_impl,
                                          donate_argnums=(0,))
            self._paged_read = jax.jit(self._paged_read_impl)
        else:
            self.page_size = 0
            self.pages_per_slot = 0
            self.num_pages = 0
            self.cache = self.lm.init_cache(slots, max_len)
        self._slot_map: Dict[str, int] = {}
        self._slots: list[Optional[SlotState]] = [None] * slots
        self._use_clock = itertools.count(1)
        #: device "pos" may diverge from host truth once any row parks (the
        #: fused scan advances pos unconditionally); set -> resync next round
        self._pos_dirty = False
        self.buckets = prefill_buckets(max_len)
        self._compiled_buckets: set = set()
        self._prefill = jax.jit(
            lambda p, b: self.lm.prefill(p, b, self.max_len))
        self._prefill_adapter = jax.jit(
            lambda p, b, a1, b1: self.lm.prefill(p, b, self.max_len,
                                                 adapter=(a1, b1)))
        # K-step fused decode: cache is DONATED — the scan updates it in
        # place instead of double-buffering the whole KV cache
        self._decode_fused = jax.jit(self._fused_impl, static_argnums=(4,),
                                     donate_argnums=(1,))
        self._decode_fused_adp = jax.jit(self._fused_adapter_impl,
                                         static_argnums=(7, 8),
                                         donate_argnums=(1,))
        if adapters is True:
            from repro.adapters.runtime import AdapterRuntime
            adapters = AdapterRuntime(cfg.d_model)
        self.adapters = adapters if adapters else None
        # slot insert: donate the full cache so admit/import is a per-slot
        # dynamic_update, not a full-cache copy
        self._slot_write = jax.jit(self._slot_write_impl, donate_argnums=(0,))
        self._slot_read = jax.jit(self._slot_read_impl)
        self._slot_read_canon = jax.jit(self._slot_read_canon_impl)
        # speculative decode: which cache leaves must be snapshotted per
        # scan step to make a round rollback-able (empty = pos-only)
        self._spec_paths = self._spec_stack_paths()
        self._spec_pending: Dict[str, dict] = {}
        self._spec_autoreg = jax.jit(self._spec_autoreg_impl,
                                     static_argnums=(4,),
                                     donate_argnums=(1,))
        self._spec_forced = jax.jit(self._spec_forced_impl,
                                    donate_argnums=(1,))

    # ------------------------------------------------------------------
    def free_slots(self) -> int:
        return sum(1 for s in self._slots if s is None)

    def has_slot(self, session_id: str) -> bool:
        return session_id in self._slot_map

    def position_of(self, session_id: str) -> int:
        """Current cache position (context length) of one session's slot —
        the authoritative payload size for migration."""
        idx = self._slot_map.get(session_id)
        if idx is None and self.hibernation is not None \
                and self.hibernation.has(session_id):
            return self.hibernation.record(session_id).position
        meta = self._slots[self._slot_map[session_id]]
        return meta.position

    # -- page-pool / session-tier accounting ----------------------------
    def free_pages(self) -> int:
        return len(self._free_page_list) if self.paged else 0

    def total_pages(self) -> int:
        """Usable pages (the scratch page is never allocatable)."""
        return self.num_pages - 1 if self.paged else 0

    def page_util(self) -> float:
        tot = self.total_pages()
        return 0.0 if tot <= 0 else 1.0 - len(self._free_page_list) / tot

    def pool_bytes(self) -> int:
        if self.paged:
            return KV.paged_cache_bytes(self.cfg, self.slots, self.max_len,
                                        self.num_pages, self.page_size)
        return KV.cache_bytes(self.cfg, self.slots, self.max_len)

    def resident_sessions(self) -> int:
        return len(self._slot_map)

    def parked_sessions(self) -> int:
        return sum(1 for s in self._slots if s is not None and s.parked)

    def hibernated_sessions(self) -> int:
        return len(self.hibernation) if self.hibernation is not None else 0

    def bound_sessions(self) -> int:
        """Sessions whose state this engine holds SOMEWHERE (resident slot
        or hibernation tier) — the number the lease layer binds against,
        decoupled from ``slots`` by paging + hibernation."""
        return self.resident_sessions() + self.hibernated_sessions()

    def is_parked(self, session_id: str) -> bool:
        idx = self._slot_map.get(session_id)
        return idx is not None and self._slots[idx] is not None \
            and self._slots[idx].parked

    def has_hibernated(self, session_id: str) -> bool:
        return self.hibernation is not None \
            and self.hibernation.has(session_id)

    def has_session(self, session_id: str) -> bool:
        return self.has_slot(session_id) or self.has_hibernated(session_id)

    # -- page allocation -------------------------------------------------
    def _alloc_pages(self, n: int) -> List[int]:
        if n > len(self._free_page_list):
            raise PagePoolExhausted(
                f"page pool exhausted: need {n} pages, "
                f"{len(self._free_page_list)} free of {self.total_pages()}")
        return [self._free_page_list.pop() for _ in range(n)]

    def _free_slot_pages(self, idx: int) -> None:
        meta = self._slots[idx]
        if meta is not None and meta.pages:
            self._free_page_list.extend(reversed(meta.pages))
            meta.pages = []
        self._block_host[idx, :] = 0

    def _ensure_pages(self, idx: int, upto_tokens: int) -> bool:
        """Grow slot ``idx``'s block table to cover token indices
        [0, upto_tokens). Under pool pressure, hibernates the coldest
        parked sessions first (LRU reclaim); raises PagePoolExhausted when
        reclamation cannot free enough."""
        meta = self._slots[idx]
        needed = min(-(-max(upto_tokens, 1) // self.page_size),
                     self.pages_per_slot)
        grow = needed - len(meta.pages)
        if grow <= 0:
            return False
        if grow > len(self._free_page_list):
            self._reclaim_pages(grow)
        new = self._alloc_pages(grow)
        meta.pages.extend(new)
        self._block_host[idx, :len(meta.pages)] = meta.pages
        return True

    def _reclaim_pages(self, need: int) -> None:
        """Hibernate coldest parked sessions until ``need`` pages are free
        (best effort; the caller's allocation raises if still short)."""
        if self.hibernation is None:
            return
        while len(self._free_page_list) < need:
            victim = None
            best = None
            for s in self._slots:
                if s is not None and s.parked and \
                        (best is None or s.last_used < best):
                    best, victim = s.last_used, s.session_id
            if victim is None:
                return
            if not self.hibernate_slot(victim):
                return          # store full: nothing more can page out

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill shapes traced so far (== jit cache entries:
        the padded width is the only shape that varies across prompts)."""
        return len(self._compiled_buckets)

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_len

    def _alloc(self, session_id: str) -> int:
        for i, s in enumerate(self._slots):
            if s is None:
                self._slot_map[session_id] = i
                return i
        raise RuntimeError("no free decode slots (lease accounting bug)")

    # ------------------------------------------------------------------
    def _batch_axis(self, path) -> int:
        """Slot/batch axis of a cache leaf: stacked families carry layers
        first ([L, b, ...]); hybrid leaves and 'pos' are slot-first."""
        keys = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
        if "pos" in keys or self.cfg.family == "hybrid":
            return 0
        return 1 if any(str(k) in ("k", "v", "conv", "ssm", "cross_k",
                                   "cross_v") for k in keys) else 0

    def _slot_write_impl(self, cache, cache1, idx):
        """Insert a batch-1 cache into slot ``idx`` (donated, traced idx)."""
        def ins(path, full, one):
            ax = self._batch_axis(path)
            return jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), idx, axis=ax)

        return jax.tree_util.tree_map_with_path(ins, cache, cache1)

    def _slot_read_impl(self, cache, idx):
        """Extract the batch-1 state of slot ``idx`` (no donation — the
        source keeps serving while migration is in flight)."""
        def ext(path, full):
            ax = self._batch_axis(path)
            return jax.lax.dynamic_slice_in_dim(full, idx, 1, axis=ax)

        return jax.tree_util.tree_map_with_path(ext, cache)

    def _slot_read_canon_impl(self, cache, idx, pos):
        """Canonical batch-1 export for linear stacked-KV families: zero
        the garbage tail (rows >= position) and report the host position,
        so identical logical state always fingerprints identically."""
        state = self._slot_read_impl(cache, idx)
        S = state["layers"]["k"].shape[2]
        valid = (jnp.arange(S) < pos)[None, None, :, None, None]
        out = dict(state)
        out["layers"] = {"k": jnp.where(valid, state["layers"]["k"], 0),
                         "v": jnp.where(valid, state["layers"]["v"], 0)}
        out["pos"] = jnp.full((1,), pos, jnp.int32)
        return out

    def _paged_install_impl(self, cache, k1, v1, idx, row, n):
        """Scatter a batch-1 linear KV cache ([L, 1, S', kh, hd]) into this
        slot's pages (cache donated). ``row`` [PPS] int32 holds the slot's
        page ids 0-padded: entries past the owned count scatter their
        (bucket-padding garbage) content into the scratch page, which is
        never read."""
        S = self.pages_per_slot * self.page_size

        def place(pool, src):
            src = src[:, 0]                              # [L, s, kh, hd]
            s = src.shape[1]
            if s < S:
                src = jnp.pad(src, ((0, 0), (0, S - s), (0, 0), (0, 0)))
            else:
                src = src[:, :S]
            src = src.reshape(src.shape[0], self.pages_per_slot,
                              self.page_size, src.shape[2],
                              src.shape[3]).astype(pool.dtype)
            return pool.at[:, row].set(src)

        return {"layers": {"k": place(cache["layers"]["k"], k1),
                           "v": place(cache["layers"]["v"], v1)},
                "block": cache["block"].at[idx].set(row),
                "pos": cache["pos"].at[idx].set(n)}

    def _paged_read_impl(self, cache, idx, pos):
        """Gather one slot's pages back into the canonical linear payload
        ([L, 1, max_len, kh, hd], tail zeroed) — the SAME bytes a dense
        engine exports for the same logical state, so fingerprints match
        across layouts and migration is layout-agnostic."""
        row = cache["block"][idx]                        # [PPS]
        valid = (jnp.arange(self.max_len) < pos)[None, :, None, None]

        def gather(pool):
            full = pool[:, row]                  # [L, PPS, page, kh, hd]
            full = full.reshape(full.shape[0], -1, full.shape[3],
                                full.shape[4])[:, :self.max_len]
            return jnp.where(valid, full, 0)[:, None]

        return {"layers": {"k": gather(cache["layers"]["k"]),
                           "v": gather(cache["layers"]["v"])},
                "pos": jnp.full((1,), pos, jnp.int32)}

    def _write_slot(self, idx: int, cache1):
        """Insert a batch-1 cache into slot ``idx`` of the engine cache."""
        self.cache = self._slot_write(self.cache, cache1, jnp.int32(idx))

    def export_slot(self, session_id: str):
        """Extract this session's state (the migration payload).

        Canonical families zero the KV tail and every family reports the
        host-side position (device pos drifts for parked rows — the fused
        scan advances it unconditionally), so the same logical state
        fingerprints identically across dense/paged layouts and across
        hibernate/resume round trips. Hibernated sessions export straight
        from the host tier: migrating a cold session needs no resume."""
        if session_id not in self._slot_map and self.has_hibernated(
                session_id):
            return self.hibernation.restore(session_id)
        idx = self._slot_map[session_id]
        meta = self._slots[idx]
        if self.paged:
            state = self._paged_read(self.cache, jnp.int32(idx),
                                     jnp.int32(meta.position))
        elif self._canonical:
            state = self._slot_read_canon(self.cache, jnp.int32(idx),
                                          jnp.int32(meta.position))
        else:
            state = dict(self._slot_read(self.cache, jnp.int32(idx)))
            state["pos"] = jnp.full((1,), meta.position, jnp.int32)
        return {"cache": state, "position": meta.position,
                "last_token": meta.last_token,
                "adapter_id": meta.adapter_id}

    def import_slot(self, session_id: str, payload) -> None:
        """Install a migrated session's state into a free slot. Raises
        AdmissionDenied when the target has no free slot — the migration
        abort cause (COMPUTE_SCARCITY), distinct from the lease-accounting
        bug the prefill path's exhaustion signals. On a paged engine the
        page allocation is part of admission: a pool too full to hold the
        payload denies the same way."""
        if self.free_slots() == 0:
            from repro.serving.state_transfer import AdmissionDenied
            raise AdmissionDenied(
                f"target admission denied: no free decode slots for "
                f"{session_id}")
        adapter_id = str(payload.get("adapter_id", ""))
        if adapter_id and (self.adapters is None
                           or not self.adapters.is_loaded(adapter_id)):
            # the adapter binding is part of the session contract: a
            # target that cannot realise it must refuse the transfer,
            # not silently continue on the base model
            from repro.serving.state_transfer import AdmissionDenied
            raise AdmissionDenied(
                f"target admission denied: adapter {adapter_id!r} not "
                f"loaded for {session_id}")
        idx = self._alloc(session_id)
        meta = SlotState(session_id, payload["position"],
                         last_token=payload["last_token"],
                         adapter_id=adapter_id,
                         last_used=next(self._use_clock))
        self._slots[idx] = meta
        if self.paged:
            try:
                self._ensure_pages(idx, max(int(payload["position"]), 1))
            except PagePoolExhausted as e:
                from repro.serving.state_transfer import AdmissionDenied
                self._slot_map.pop(session_id, None)
                self._slots[idx] = None
                raise AdmissionDenied(str(e)) from e
            row = np.zeros(self.pages_per_slot, np.int32)
            row[:len(meta.pages)] = meta.pages
            self.cache = self._paged_install(
                self.cache, payload["cache"]["layers"]["k"],
                payload["cache"]["layers"]["v"], jnp.int32(idx),
                jnp.asarray(row), jnp.int32(payload["position"]))
        else:
            self._write_slot(idx, payload["cache"])

    def _free_slot(self, session_id: str) -> None:
        """Free the slot and pages only — hibernated state (if any) stays."""
        idx = self._slot_map.pop(session_id, None)
        if idx is not None:
            if self.paged:
                self._free_slot_pages(idx)
            self._slots[idx] = None

    def release_slot(self, session_id: str) -> None:
        """End of session: free slot/pages AND purge any hibernated copy."""
        self._free_slot(session_id)
        if self.hibernation is not None:
            self.hibernation.drop(session_id)

    # -- tiering: resident <-> parked <-> hibernated ---------------------
    def park_slot(self, session_id: str) -> None:
        """Mark a resident session idle. It keeps its slot and pages but
        rides subsequent decode rounds with active=False — state frozen
        bit-exactly, resume is free."""
        meta = self._slots[self._slot_map[session_id]]
        meta.parked = True
        self._pos_dirty = True

    def hibernate_slot(self, session_id: str, *,
                       now: Optional[float] = None) -> bool:
        """Page a resident session out to the host tier, freeing its slot
        and pages for other sessions. Returns False — with the session left
        resident, state intact — when a capacity-bounded store refuses the
        payload: heartbeat/reclaim callers degrade (skip, retry next tick)
        instead of dying mid-tick. Records are stamped with ``now`` (or the
        engine clock) so store-side TTL/LRU ordering is real."""
        if self.hibernation is None:
            raise RuntimeError(
                f"cannot hibernate {session_id}: engine has no "
                f"hibernation store")
        if now is None:
            now = self.clock.now() if self.clock is not None else 0.0
        payload = self.export_slot(session_id)
        try:
            self.hibernation.put(session_id, payload, now=now)
        except MemoryError:
            # store_full is counted by the store itself; the session stays
            # resident/parked and a later tick retries once space frees up
            return False
        self._free_slot(session_id)
        return True

    def resume_slot(self, session_id: str) -> None:
        """Re-import a hibernated session. The store record is dropped only
        AFTER the import succeeds — a refused resume (no slot / no pages)
        must not lose the only copy of the state."""
        payload = self.hibernation.restore(session_id)
        self.import_slot(session_id, payload)
        self.hibernation.drop(session_id)

    def resume_session(self, session_id: str) -> None:
        """Bring a bound session back to active-resident from any tier."""
        idx = self._slot_map.get(session_id)
        if idx is not None:
            meta = self._slots[idx]
            meta.parked = False
            meta.last_used = next(self._use_clock)
            return
        if self.has_hibernated(session_id):
            self.resume_slot(session_id)
            return
        raise KeyError(f"unknown session {session_id}")

    # -- adapter lifecycle ------------------------------------------------
    def load_adapter(self, adapter_id: str, a, b) -> int:
        """Install adapter weights into this engine's device tables;
        idempotent. Returns the table index."""
        if self.adapters is None:
            raise RuntimeError("engine has no adapter runtime")
        return self.adapters.load(adapter_id, a, b)

    def unload_adapter(self, adapter_id: str) -> None:
        """Evict an adapter. Refused while any bound session (resident
        or parked) still references it — unloading under a live binding
        would silently continue those sessions on the base model."""
        if self.adapters is None:
            raise RuntimeError("engine has no adapter runtime")
        users = [s.session_id for s in self._slots
                 if s is not None and s.adapter_id == adapter_id]
        if users:
            raise RuntimeError(
                f"adapter {adapter_id!r} still bound by {users}")
        self.adapters.unload(adapter_id)

    # ------------------------------------------------------------------
    def prefill_session(self, session_id: str, prompt: np.ndarray, *,
                        adapter_id: str = "") -> dict:
        """Admit a session: run prefill, install the cache, return TTFT.

        The prompt is right-padded to its power-of-two bucket with the true
        length passed as a traced scalar — the whole mix of prompt lengths
        compiles at most ``len(self.buckets)`` prefill variants.

        ``adapter_id`` binds a tenant adapter for the session's lifetime;
        it must already be loaded on this engine (ValueError otherwise —
        the serving plane maps that to NO_FEASIBLE_BINDING).
        """
        t0 = time.perf_counter()
        aidx = 0
        if adapter_id:
            if self.adapters is None:
                raise ValueError(
                    f"engine has no adapter runtime; cannot bind "
                    f"{adapter_id!r} for {session_id}")
            try:
                aidx = self.adapters.index_of(adapter_id)
            except KeyError:
                raise ValueError(
                    f"adapter {adapter_id!r} not loaded on this engine "
                    f"for {session_id}")
        n = len(prompt)
        if n > self.max_len:
            # refuse rather than silently truncate: a truncated prefill
            # would condition generation on a clipped prefix while
            # position_of()/migration payload sizing report the full length
            raise ValueError(
                f"prompt of {n} tokens exceeds engine max_len "
                f"{self.max_len} for {session_id}")
        width = self._bucket(n)
        padded = np.zeros(width, np.int32)
        padded[:n] = prompt
        self._compiled_buckets.add(width)
        batch = {"tokens": jnp.asarray(padded[None, :], jnp.int32),
                 "length": jnp.int32(n)}
        if aidx:
            logits, cache1 = self._prefill_adapter(
                self.params, batch, self.adapters.A[aidx],
                self.adapters.B[aidx])
        else:
            logits, cache1 = self._prefill(self.params, batch)
        tok = int(jnp.argmax(logits[0]))
        idx = self._alloc(session_id)
        meta = SlotState(session_id, position=n, tokens_generated=1,
                         last_token=tok, adapter_id=adapter_id,
                         last_used=next(self._use_clock))
        self._slots[idx] = meta
        if self.paged:
            try:
                # only ceil(n / page) pages — NOT max_len worth: the whole
                # point of paging is that admission reserves what the
                # session actually uses
                self._ensure_pages(idx, n)
            except PagePoolExhausted:
                self._slot_map.pop(session_id, None)
                self._slots[idx] = None
                raise
            row = np.zeros(self.pages_per_slot, np.int32)
            row[:len(meta.pages)] = meta.pages
            self.cache = self._paged_install(
                self.cache, cache1["layers"]["k"], cache1["layers"]["v"],
                jnp.int32(idx), jnp.asarray(row), jnp.int32(n))
        else:
            self._write_slot(idx, cache1)
        return {"first_token": tok,
                "ttfb_ms": (time.perf_counter() - t0) * 1e3}

    # ------------------------------------------------------------------
    def _fused_impl(self, params, cache, last, active, steps: int):
        """K decode steps in one jitted scan. ``last``: [slots] int32 token
        feedback; ``active``: [slots] bool — inactive slots keep feeding
        their (zero) token so a fused chunk is bit-identical to K sequential
        single-step rounds regardless of who shares the batch.
        Returns (cache, token block [slots, K])."""
        def step(carry, _):
            c, fed = carry
            logits, c = self.lm.decode_step(params, c, fed[:, None],
                                            active=active)
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            fed = jnp.where(active, nxt, fed)
            return (c, fed), fed

        (cache, _), toks = jax.lax.scan(step, (cache, last), None,
                                        length=steps)
        return cache, jnp.moveaxis(toks, 0, 1)          # [slots, K]

    def _fused_adapter_impl(self, params, cache, last, active, aidx,
                            adp_a, adp_b, steps: int, route: str):
        """Adapter-aware variant of the fused K-step scan: the per-slot
        int32 table ``aidx`` gathers stacked LoRA A/B rows inside every
        decode step. Tables are traced arguments (NOT closure constants),
        so load/unload between rounds needs no retrace — only the table
        contents change."""
        def step(carry, _):
            c, fed = carry
            logits, c = self.lm.decode_step(
                params, c, fed[:, None], active=active,
                adapter=(adp_a, adp_b, aidx, route))
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            fed = jnp.where(active, nxt, fed)
            return (c, fed), fed

        (cache, _), toks = jax.lax.scan(step, (cache, last), None,
                                        length=steps)
        return cache, jnp.moveaxis(toks, 0, 1)          # [slots, K]

    # ------------------------------------------------------------------
    # Speculative decode: rollback-able scan rounds.
    #
    # Both the draft and verify role run the SAME shape of round: a
    # (γ+1)-step fused scan consuming [ℓ, t_1..t_γ] (ℓ = the slot's
    # unconsumed last token), whose post-step state at index n is exactly
    # the engine state after committing n of the γ candidate tokens. The
    # draft consumes its own outputs (autoregressive, producing the
    # proposals), the verifier consumes the proposals teacher-forced
    # (producing the target-greedy continuation y_0..y_γ in ONE fused
    # forward). ``spec_accept(n, y_n)`` then restores the index-n
    # snapshot: committed stream = d_1..d_n, y_n — bitwise what
    # target-only greedy decode would have produced.
    #
    # Rollback cost depends on the cache family: full-attention caches
    # written at absolute positions need NO snapshots (rows >= pos are
    # never attended and later overwritten — pos-only rollback, including
    # paged); recurrent/ring-buffer leaves (ssm conv/ssm, hybrid conv/h
    # and windowed k/v, sliding-window k/v) are destructive per step and
    # are stacked by the scan.
    # ------------------------------------------------------------------
    def _spec_stack_paths(self) -> List[tuple]:
        """Cache-leaf paths that must be snapshotted per scan step."""
        if self.cfg.family in ("dense", "moe", "encdec") \
                and not self.cfg.sliding_window:
            return []                       # pos-only rollback
        paths: List[tuple] = []
        layers = self.cache["layers"]
        if isinstance(layers, tuple):       # hybrid: per-layer dicts
            for i, layer in enumerate(layers):
                for key in sorted(layer.keys()):
                    paths.append(("layers", i, key))
        else:                               # stacked-layer dict carry
            for key in sorted(layers.keys()):
                if key in ("cross_k", "cross_v"):
                    continue                # static after prefill
                paths.append(("layers", key))
        return paths

    @staticmethod
    def _leaf_get(tree, path):
        for p in path:
            tree = tree[p]
        return tree

    @classmethod
    def _leaf_set(cls, tree, path, value):
        if not path:
            return value
        head = path[0]
        if isinstance(tree, tuple):
            return tuple(cls._leaf_set(t, path[1:], value) if i == head
                         else t for i, t in enumerate(tree))
        out = dict(tree)
        out[head] = cls._leaf_set(tree[head], path[1:], value)
        return out

    def _spec_autoreg_impl(self, params, cache, last, active, steps: int):
        """γ+1 autoregressive steps, snapshotting rollback leaves.
        Returns (cache, tokens [slots, steps], stacks [steps, ...])."""
        def step(carry, _):
            c, fed = carry
            logits, c = self.lm.decode_step(params, c, fed[:, None],
                                            active=active)
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            fed = jnp.where(active, nxt, fed)
            snap = [self._leaf_get(c, p) for p in self._spec_paths]
            return (c, fed), (fed, snap)

        (cache, _), (toks, stacks) = jax.lax.scan(
            step, (cache, last), None, length=steps)
        return cache, jnp.moveaxis(toks, 0, 1), stacks

    def _spec_forced_impl(self, params, cache, active, forced):
        """Teacher-forced scan over ``forced`` [slots, steps]: step t
        consumes forced[:, t] and emits the greedy next token — the one
        fused verify forward. Same snapshot discipline as the
        autoregressive round."""
        def step(c, tok):
            logits, c = self.lm.decode_step(params, c, tok[:, None],
                                            active=active)
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            snap = [self._leaf_get(c, p) for p in self._spec_paths]
            return c, (nxt, snap)

        cache, (ys, stacks) = jax.lax.scan(
            step, cache, jnp.moveaxis(forced, 0, 1))
        return cache, jnp.moveaxis(ys, 0, 1), stacks

    def _spec_prologue(self, session_id: str, gamma: int):
        """Shared admission for a spec round: slot lookup, bounds, page
        growth, device pos/block resync from host truth (a spec round
        always ends with host-side position authority)."""
        idx = self._slot_map[session_id]
        meta = self._slots[idx]
        if meta.adapter_id:
            raise ValueError(
                f"speculative decode does not support adapter-bound "
                f"sessions ({session_id} binds {meta.adapter_id!r})")
        if gamma < 1:
            raise ValueError("spec round needs gamma >= 1")
        if meta.position + gamma + 1 > self.max_len:
            raise ValueError(
                f"spec round of gamma={gamma} overruns max_len "
                f"{self.max_len} from position {meta.position}")
        if session_id in self._spec_pending:
            raise RuntimeError(
                f"spec round already pending for {session_id}; "
                f"spec_accept it first")
        last = np.zeros(self.slots, np.int32)
        active = np.zeros(self.slots, bool)
        last[idx] = meta.last_token
        active[idx] = True
        if self.paged:
            self._ensure_pages(idx, meta.position + gamma + 2)
        pos_host = np.zeros(self.slots, np.int32)
        for i, s in enumerate(self._slots):
            if s is not None:
                pos_host[i] = s.position
        cache = dict(self.cache)
        cache["pos"] = jnp.asarray(pos_host)
        if self.paged:
            cache["block"] = jnp.asarray(self._block_host)
        self.cache = cache
        return idx, meta, last, active

    def spec_round(self, session_id: str, gamma: int) -> List[int]:
        """Draft role: propose γ tokens autoregressively from the current
        state. The slot's host state does NOT advance — the round is
        pending until ``spec_accept`` commits a prefix of it. Only one
        slot runs; co-resident slots ride with active=False (frozen), so
        the snapshots are restorable wholesale."""
        gamma = int(gamma)
        idx, meta, last, active = self._spec_prologue(session_id, gamma)
        pre = [self._leaf_get(self.cache, p).copy()
               for p in self._spec_paths]
        self.cache, toks, stacks = self._spec_autoreg(
            self.params, self.cache, jnp.asarray(last),
            jnp.asarray(active), gamma + 1)
        toks = np.asarray(toks)
        self._spec_pending[session_id] = {"stacks": stacks, "pre": pre,
                                          "base_pos": meta.position,
                                          "gamma": gamma}
        self._pos_dirty = True      # device pos ran ahead of host truth
        return [int(t) for t in toks[idx, :gamma]]      # d_1..d_γ

    def spec_grade(self, session_id: str, tokens: List[int]) -> List[int]:
        """Verify role: consume ``tokens`` = [d_1..d_γ] teacher-forced in
        one fused forward and return the target-greedy continuation
        y_0..y_γ (y_t = greedy next after [.., ℓ, d_1..d_t]). Pending
        until ``spec_accept``."""
        gamma = len(tokens)
        idx, meta, last, active = self._spec_prologue(session_id, gamma)
        pre = [self._leaf_get(self.cache, p).copy()
               for p in self._spec_paths]
        forced = np.zeros((self.slots, gamma + 1), np.int32)
        forced[idx, 0] = meta.last_token
        forced[idx, 1:] = tokens
        self.cache, ys, stacks = self._spec_forced(
            self.params, self.cache, jnp.asarray(active),
            jnp.asarray(forced))
        ys = np.asarray(ys)
        self._spec_pending[session_id] = {"stacks": stacks, "pre": pre,
                                          "base_pos": meta.position,
                                          "gamma": gamma}
        self._pos_dirty = True
        return [int(t) for t in ys[idx]]                # y_0..y_γ

    def spec_accept(self, session_id: str, n_accept: int,
                    last_token: int) -> None:
        """Commit the longest agreeing prefix: restore the index-n
        snapshot (state after consuming ℓ, d_1..d_n), advance the host
        position by n+1 committed tokens, and make ``last_token`` (= y_n,
        the verifier's correction/extension) the new unconsumed token.
        n ∈ [0, γ]; n = γ accepts the whole round."""
        pend = self._spec_pending.pop(session_id)
        n = int(n_accept)
        if not (0 <= n <= pend["gamma"]):
            raise ValueError(
                f"n_accept {n} outside [0, {pend['gamma']}]")
        cache = self.cache
        for path, stacked in zip(self._spec_paths, pend["stacks"]):
            cache = self._leaf_set(cache, path, stacked[n])
        self.cache = cache
        idx = self._slot_map[session_id]
        meta = self._slots[idx]
        meta.position = pend["base_pos"] + n + 1
        meta.last_token = int(last_token)
        meta.tokens_generated += n + 1
        meta.last_used = next(self._use_clock)
        self._pos_dirty = True      # next round resyncs device pos

    def spec_abort(self, session_id: str) -> None:
        """Drop a pending round without committing anything: restore the
        pre-round snapshot of every destructive leaf (host position never
        advanced; device pos resyncs on the next round)."""
        pend = self._spec_pending.pop(session_id, None)
        if pend is not None:
            cache = self.cache
            for path, leaf in zip(self._spec_paths, pend["pre"]):
                cache = self._leaf_set(cache, path, leaf)
            self.cache = cache
        self._pos_dirty = True

    def override_last_token(self, session_id: str, token: int) -> None:
        """Re-point the slot's unconsumed token at an externally committed
        one. The draft half of a split session decodes the VERIFIER's
        token stream, not its own: after the draft-side prefill (and
        after every accepted round) the next token it must consume is
        whatever the verifier committed."""
        meta = self._slots[self._slot_map[session_id]]
        meta.last_token = int(token)

    def decode_round(self, steps: Optional[int] = None
                     ) -> Dict[str, Union[int, List[int]]]:
        """Continuous-batching decode for every active slot.

        ``steps=None`` — legacy single-step form: {session: token}.
        ``steps=K``    — fused K-step chunk: {session: [token, ...] * K},
        produced by ONE dispatch and ONE device→host transfer.
        """
        if not self._slot_map:
            return {}
        k = 1 if steps is None else max(1, int(steps))
        last = np.zeros(self.slots, np.int32)
        active = np.zeros(self.slots, bool)
        any_parked = False
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            if s.parked:
                any_parked = True
                continue
            last[i] = s.last_token
            active[i] = True
        if not active.any():
            return {}
        if self.paged:
            # grow block tables BEFORE the fused chunk — the scan cannot
            # allocate mid-flight; under pressure this hibernates coldest
            # parked sessions or raises PagePoolExhausted
            for i, s in enumerate(self._slots):
                if s is not None and not s.parked:
                    self._ensure_pages(i, s.position + k)
        if self.paged or any_parked or self._pos_dirty:
            # resync device pos (and block table) from host truth: parked
            # rows' device pos advances inside the fused scan even though
            # their state is frozen
            pos_host = np.zeros(self.slots, np.int32)
            for i, s in enumerate(self._slots):
                if s is not None:
                    pos_host[i] = s.position
            cache = dict(self.cache)
            cache["pos"] = jnp.asarray(pos_host)
            if self.paged:
                cache["block"] = jnp.asarray(self._block_host)
            self.cache = cache
            self._pos_dirty = any_parked
        if self.adapters is not None:
            aidx = np.zeros(self.slots, np.int32)
            for i, s in enumerate(self._slots):
                if s is not None and s.adapter_id:
                    aidx[i] = self.adapters.index_of(s.adapter_id)
            self.cache, block = self._decode_fused_adp(
                self.params, self.cache, jnp.asarray(last),
                jnp.asarray(active), jnp.asarray(aidx),
                self.adapters.A, self.adapters.B, k, self.adapters.route)
        else:
            self.cache, block = self._decode_fused(
                self.params, self.cache, jnp.asarray(last),
                jnp.asarray(active), k)
        block = np.asarray(block)                        # [slots, K]
        out: Dict[str, Union[int, List[int]]] = {}
        for i, s in enumerate(self._slots):
            if s is None or s.parked:
                continue
            s.last_token = int(block[i, -1])
            s.position += k
            s.tokens_generated += k
            s.last_used = next(self._use_clock)
            out[s.session_id] = (int(block[i, 0]) if steps is None
                                 else [int(t) for t in block[i]])
        return out

    # ------------------------------------------------------------------
    def serve(self, session_id: str, prompt_tokens: int, gen_tokens: int,
              *, prompt: Optional[np.ndarray] = None,
              chunk: int = 16, adapter_id: str = "") -> dict:
        """Unary convenience: prefill + chunked decode for one session.

        Synthetic prompts are crc32-seeded (NOT ``hash()``, which varies
        per process under PYTHONHASHSEED and would break reproducible
        traces and cross-process fingerprint checks)."""
        rng = np.random.default_rng(
            zlib.crc32(session_id.encode()) % 2**31)
        if prompt is None:
            prompt = rng.integers(0, self.cfg.vocab_size,
                                  size=prompt_tokens).astype(np.int32)
        t0 = time.perf_counter()
        pre = self.prefill_session(session_id, prompt,
                                   adapter_id=adapter_id)
        toks = [pre["first_token"]]
        remaining = gen_tokens - 1
        while remaining > 0:
            # pow2 chunk schedule: O(log chunk) compiled scan variants
            k = min(chunk, 1 << (remaining.bit_length() - 1))
            out = self.decode_round(steps=k)
            toks.extend(out[session_id])
            remaining -= k
        self.release_slot(session_id)
        total_ms = (time.perf_counter() - t0) * 1e3
        return {"tokens": toks, "ttfb_ms": pre["ttfb_ms"],
                "latency_ms": total_ms}
