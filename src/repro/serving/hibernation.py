"""Host-memory hibernation tier for idle session state.

A bound AI Session whose lease is idle costs no device memory: the engine
exports its slot state (the same canonical payload make-before-break
migration moves — see ``repro.serving.state_transfer``), parks the bytes
here as host numpy arrays under the payload's fingerprint, and frees the
slot and its KV pages. The next ``serve()`` re-imports transparently.

This is the tiering that decouples *bound* sessions from *resident* slots:
resident (device, active) → parked (device, idle) → hibernated (host).
Every restore re-fingerprints the stored payload before handing it back, so
host-side corruption surfaces as the same IOError the migration wire check
raises, never as silently wrong tokens.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import numpy as np

from repro.serving import state_transfer


def _to_host(payload) -> dict:
    """Deep-copy a slot payload to host numpy (device buffers must not be
    pinned by the store — freeing the pages is the whole point)."""
    return {"cache": jax.tree.map(lambda l: np.array(l, copy=True),
                                  payload["cache"]),
            "position": int(payload["position"]),
            "last_token": int(payload["last_token"]),
            "adapter_id": str(payload.get("adapter_id", ""))}


@dataclass
class HibernationRecord:
    payload: dict                 # host-numpy slot payload
    fingerprint: str              # sha256 over cache leaves + position
    nbytes: int
    position: int
    hibernated_at: float = 0.0    # store clock; TTL policy lives in callers


class HibernationStore:
    """Host-memory session-state store keyed by session id."""

    def __init__(self, capacity_bytes: Optional[int] = None):
        self.capacity_bytes = capacity_bytes
        self._records: Dict[str, HibernationRecord] = {}
        self.puts = 0
        self.restores = 0
        self.verify_failures = 0
        #: refused puts on a capacity-bounded store — the heartbeat tick
        #: reads this through PlaneLoad as back-pressure, never as a crash
        self.store_full = 0

    # ------------------------------------------------------------------
    def put(self, session_id: str, payload, *, now: float = 0.0
            ) -> HibernationRecord:
        host = _to_host(payload)
        nbytes = state_transfer.payload_bytes(host)
        if self.capacity_bytes is not None:
            held = self.bytes() - (self._records[session_id].nbytes
                                   if session_id in self._records else 0)
            if held + nbytes > self.capacity_bytes:
                self.store_full += 1
                raise MemoryError(
                    f"hibernation store full: {held + nbytes} > "
                    f"{self.capacity_bytes} bytes for {session_id}")
        rec = HibernationRecord(host, state_transfer.fingerprint(host),
                                nbytes, host["position"], now)
        self._records[session_id] = rec
        self.puts += 1
        return rec

    def restore(self, session_id: str) -> dict:
        """Verified copy of the stored payload. The record stays until the
        caller ``drop``s it — resume must not lose the only copy when the
        re-import is refused (no slot / no pages)."""
        rec = self._records[session_id]
        fp = state_transfer.fingerprint(rec.payload)
        if fp != rec.fingerprint:
            self.verify_failures += 1
            raise IOError(f"hibernated state corruption for {session_id}: "
                          f"{rec.fingerprint} != {fp}")
        self.restores += 1
        return _to_host(rec.payload)

    def drop(self, session_id: str) -> bool:
        return self._records.pop(session_id, None) is not None

    # ------------------------------------------------------------------
    def has(self, session_id: str) -> bool:
        return session_id in self._records

    def record(self, session_id: str) -> Optional[HibernationRecord]:
        return self._records.get(session_id)

    def sessions(self):
        return list(self._records)

    def bytes(self) -> int:
        return sum(r.nbytes for r in self._records.values())

    def __len__(self) -> int:
        return len(self._records)
