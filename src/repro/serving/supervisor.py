"""Per-site supervisor: health, graceful drain, crash re-anchoring.

Production serving is mostly what happens when things die. This module is
the fleet-ops layer over one ServingPlane/engine pair — modeled on
config-driven process supervision (liveness/readiness probes, explicit
exit-behavior semantics) — that converts the paper's Eq. 12 failure-cause
taxonomy from a table into measured behavior:

* **probe** — liveness is "the heartbeat tick completes" (``plane.load()``,
  the exact path ``Orchestrator.heartbeat`` drives, including the
  hibernation idle-TTL tick); readiness is "live AND admitting". Probe
  results feed ``Analytics.observe_site`` so the ξ loop sees supervisor
  cadence even for sessions that stopped heartbeating. A probe never
  raises: ``miss_threshold`` consecutive failed probes escalate
  SUSPECT → DEAD and fire the crash path.
* **drain** — graceful exit: stop admitting (submits reject, accounted) →
  finish every in-flight and queued request (zero failed) → migrate bound
  sessions out via the existing make-before-break ``PlaneTransferPath`` →
  hibernate what cannot move (host store survives the exiting process) →
  deny the site in analytics.
* **crash** — abrupt death: the lease table and device state are gone.
  In-flight and queued requests fail attributably (COMPUTE_SCARCITY: the
  anchor's compute vanished mid-contract), the site is marked dead
  everywhere (leases void ⇒ v_cmp False, DISCOVER exclusion ``site-dead``),
  and every orphaned session re-anchors through
  ``Orchestrator.reanchor`` — resuming from the hibernation store when it
  holds a copy, fresh-context re-prepare otherwise.

Eq. 12 attribution for supervisor-detected failures:

====================================  =============================
event                                 cause
====================================  =============================
in-flight request on crashed site     COMPUTE_SCARCITY
queued request on crashed site        COMPUTE_SCARCITY
re-anchor: no live candidate          NO_FEASIBLE_BINDING
re-anchor: all candidates saturated   COMPUTE_SCARCITY
re-anchor: exceeded τ_mig             DEADLINE_EXPIRY
corrupt hibernated copy on restore    (none — degrades to fresh context)
====================================  =============================
"""

from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.failures import FailureCause
from repro.core.session import SessionState
from repro.serving.plane import PlaneLoad


class SiteHealth(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"          # missed probes, below the death threshold
    DRAINING = "draining"
    DRAINED = "drained"
    DEAD = "dead"


@dataclass
class ProbeResult:
    site_id: str
    live: bool                   # heartbeat tick completed
    ready: bool                  # live AND admitting (not draining/dead)
    state: SiteHealth
    load: Optional[PlaneLoad] = None
    error: str = ""
    misses: int = 0


@dataclass
class DrainReport:
    site_id: str
    migrated: int = 0            # moved out make-before-break
    hibernated: int = 0          # parked to the host store (couldn't move)
    stranded: int = 0            # neither migrated nor hibernated
    failed_inflight: int = 0     # in-flight requests failed during drain
    completed: int = 0           # requests finished while draining
    sessions: int = 0            # bound sessions at drain start


@dataclass
class CrashReport:
    site_id: str
    orphaned: int = 0            # sessions anchored here at crash
    reanchored: int = 0
    restored: int = 0            # re-anchored AND state resumed from store
    lost: int = 0                # re-anchor failed (session FAILED)
    failed_inflight: int = 0     # running+queued requests attributed
    causes: Dict[str, int] = field(default_factory=dict)
    recovery_ms: List[float] = field(default_factory=list)  # per session

    @property
    def survival_frac(self) -> float:
        return self.reanchored / self.orphaned if self.orphaned else 1.0


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    return ys[min(int(q * (len(ys) - 1) + 0.999), len(ys) - 1)]


class SiteSupervisor:
    """Supervises ONE execution site of an orchestrator."""

    def __init__(self, orch, site_id: str, *, miss_threshold: int = 3):
        self.orch = orch
        self.site_id = site_id
        self.site = orch.sites[site_id]
        self.state = SiteHealth.HEALTHY
        self.miss_threshold = miss_threshold
        self._misses = 0

    # ------------------------------------------------------------------
    # health
    # ------------------------------------------------------------------
    def probe(self) -> ProbeResult:
        """One liveness/readiness probe. Never raises — a backend that dies
        on its own heartbeat tick IS the crash signal, not a supervisor
        crash. ``miss_threshold`` consecutive failures declare the site
        dead and fire the full crash path (attribution + re-anchoring)."""
        if self.state is SiteHealth.DEAD:
            return ProbeResult(self.site_id, False, False, self.state,
                               error="site is dead", misses=self._misses)
        plane = self.site.plane
        if plane is None:
            # control-plane-only site: the lease table is process-local,
            # live by definition; readiness tracks supervisor state
            return ProbeResult(self.site_id, True,
                               self.state is SiteHealth.HEALTHY, self.state)
        breakers = getattr(self.orch, "breakers", None)
        try:
            load = plane.load()
        except Exception as e:                      # noqa: BLE001
            self._misses += 1
            if breakers is not None:
                breakers.record(self.site_id, False)
            if self._misses >= self.miss_threshold:
                self.crash(detail=f"probe: {type(e).__name__}: {e}")
            elif self.state is SiteHealth.HEALTHY:
                self.state = SiteHealth.SUSPECT
            return ProbeResult(self.site_id, False, False, self.state,
                               error=f"{type(e).__name__}: {e}",
                               misses=self._misses)
        self._misses = 0
        if breakers is not None:
            # a completed heartbeat tick is the half-open probe success that
            # re-closes this site's circuit for DISCOVER
            breakers.record(self.site_id, True)
        if self.state is SiteHealth.SUSPECT:
            self.state = SiteHealth.HEALTHY
        # supervisor cadence feeds the ξ loop: site health is observed even
        # when no session heartbeat lands on this site
        self.orch.analytics.observe_site(
            self.site_id, utilization=self.site.utilization(),
            queue_depth=load.queue_depth, arrival_rate=load.arrival_rate,
            page_util=load.page_util)
        ready = self.state is SiteHealth.HEALTHY \
            and getattr(plane, "admitting", True)
        return ProbeResult(self.site_id, True, ready, self.state, load=load)

    # ------------------------------------------------------------------
    # session census
    # ------------------------------------------------------------------
    def _anchored_sessions(self) -> list:
        """Sessions whose binding anchors them to this site, in a state
        worth recovering. Checks the state machine, NOT ``committed()`` —
        a crashed site has already voided v_cmp for exactly the sessions
        we must recover."""
        out = []
        for s in self.orch.sessions.values():
            b = getattr(s, "binding", None)
            state = getattr(s, "state", None)
            if b is not None and b.site_id == self.site_id and \
                    state in (SessionState.COMMITTED, SessionState.MIGRATING):
                out.append(s)
        return out

    # ------------------------------------------------------------------
    # graceful drain
    # ------------------------------------------------------------------
    def drain(self) -> DrainReport:
        """Graceful exit. In-flight work finishes (never fails), then every
        bound session leaves: make-before-break migration out first,
        hibernation to the surviving host store for whatever cannot move.
        The site ends DRAINED and analytics-denied (discovery steers away),
        with its lease table intact — drain is an exit, not a crash."""
        self.state = SiteHealth.DRAINING
        plane = self.site.plane
        report = DrainReport(self.site_id)
        # steer new placements away while we move sessions out
        self.orch.analytics.deny_site(self.site_id)
        if plane is not None:
            plane.admitting = False
            plane.drain()                 # in-flight + queued all complete
            for res in self.orch.record_results(self.site):
                if res.failed is not None:
                    report.failed_inflight += 1
                else:
                    report.completed += 1
        sessions = self._anchored_sessions()
        report.sessions = len(sessions)
        backend = plane.backend if plane is not None else None
        engine = getattr(backend, "engine", None)
        for session in sessions:
            out = self.orch.migrations.migrate(session, session.zone)
            if out.migrated:
                report.migrated += 1
                continue
            sid = session.session_id
            if engine is not None and \
                    getattr(engine, "hibernation", None) is not None:
                if engine.has_hibernated(sid):
                    report.hibernated += 1      # already in the host tier
                    continue
                if engine.has_slot(sid) and engine.hibernate_slot(sid):
                    report.hibernated += 1
                    continue
            report.stranded += 1
        self.state = SiteHealth.DRAINED
        return report

    # ------------------------------------------------------------------
    # crash
    # ------------------------------------------------------------------
    def crash(self, detail: str = "site crashed") -> CrashReport:
        """Abrupt site death. Device state and the lease table are gone;
        the hibernation store (host memory) survives. Attribution first,
        then AI-PAGING re-anchoring for every orphan — per-session recovery
        wall time is what the recovery bench reports as p50/p99."""
        plane = self.site.plane
        # split sessions first, while the lease table is still intact: a
        # dead VERIFY anchor degrades its splits to edge-only (they keep
        # their edge binding and never appear in the orphan census below);
        # a dead EDGE anchor dissolves the split and falls through to the
        # normal re-anchoring path
        splits = getattr(self.orch, "splits", None)
        if splits is not None:
            splits.on_site_dead(self.site_id)
        # the census must run BEFORE leases are voided: these sessions stop
        # being distinguishable once the lease table clears
        orphans = self._anchored_sessions()
        store = None
        if plane is not None:
            backend = plane.backend
            store_fn = getattr(backend, "_store", None)
            store = store_fn() if callable(store_fn) else None
        self.state = SiteHealth.DEAD
        self.site.mark_dead(detail)
        self.orch.analytics.mark_site_dead(self.site_id)
        report = CrashReport(self.site_id, orphaned=len(orphans))
        if plane is not None:
            report.failed_inflight = plane.fail_all(
                FailureCause.COMPUTE_SCARCITY)
            self.orch.record_results(self.site)   # attribution → telemetry
        for session in orphans:
            t0 = time.perf_counter()
            out = self.orch.reanchor(session, state_source=store)
            if out.ok:
                report.reanchored += 1
                report.restored += int(out.restored)
                report.recovery_ms.append((time.perf_counter() - t0) * 1e3)
            else:
                report.lost += 1
                key = out.cause.value if out.cause else "unknown"
                report.causes[key] = report.causes.get(key, 0) + 1
        return report

    def revive(self) -> None:
        """Recovered process: fresh lease table, admission reopens, the
        site returns to DISCOVER. Sessions do NOT return — they re-anchored
        elsewhere; new establishes may land here again."""
        self.site.mark_alive()
        self.orch.analytics.mark_site_alive(self.site_id)
        self.orch.analytics.allow_site(self.site_id)
        if self.site.plane is not None:
            self.site.plane.admitting = True
        self.state = SiteHealth.HEALTHY
        self._misses = 0


class FleetSupervisor:
    """One SiteSupervisor per local site of an orchestrator — the sweep a
    deployment runs at health-check cadence, plus named drain/crash entry
    points for operations and chaos harnesses."""

    def __init__(self, orch, *, miss_threshold: int = 3):
        self.orch = orch
        self.supervisors: Dict[str, SiteSupervisor] = {
            sid: SiteSupervisor(orch, sid, miss_threshold=miss_threshold)
            for sid, site in orch.sites.items()
            if not getattr(site, "is_guest_view", False)}

    def __getitem__(self, site_id: str) -> SiteSupervisor:
        return self.supervisors[site_id]

    def probe_all(self) -> Dict[str, ProbeResult]:
        return {sid: sup.probe() for sid, sup in self.supervisors.items()}

    def ready(self) -> Dict[str, bool]:
        return {sid: r.ready for sid, r in self.probe_all().items()}

    def drain(self, site_id: str) -> DrainReport:
        return self.supervisors[site_id].drain()

    def crash(self, site_id: str, detail: str = "site crashed") -> CrashReport:
        return self.supervisors[site_id].crash(detail)

    def revive(self, site_id: str) -> None:
        self.supervisors[site_id].revive()
