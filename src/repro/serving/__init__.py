from repro.serving.engine import InferenceEngine  # noqa: F401
from repro.serving.scheduler import QoSScheduler, Request  # noqa: F401
