from repro.serving.engine import InferenceEngine, PagePoolExhausted  # noqa: F401
from repro.serving.hibernation import HibernationStore  # noqa: F401
from repro.serving.scheduler import QoSScheduler, Request, SchedulerStats  # noqa: F401
from repro.serving.plane import (ServingPlane, PlaneResult, PlaneLoad,  # noqa: F401
                                 RealEngineBackend, SimulatedEngine)
