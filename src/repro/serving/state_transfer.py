"""Session-state transfer: the data plane of make-before-break migration.

``transfer(src_engine, dst_engine, session_id)`` exports the slot state on
the source anchor, re-shards it for the destination (between meshes this is
a ``jax.device_put`` with the destination shardings; on one host it is a
copy), verifies integrity, and installs it into a destination slot while
the source keeps serving. Only after the destination confirms does the
caller release the source slot (MigrationController drives the ordering).

Family-specific payloads (DESIGN.md §4):
    dense/moe : full or windowed KV pages       (largest payload)
    hybrid    : RG-LRU states + window rings
    ssm       : conv + SSD states               (O(1) in context — cheapest)
"""

from __future__ import annotations

import hashlib
import time

import jax
import numpy as np


def payload_bytes(payload) -> int:
    return int(sum(np.asarray(l).nbytes
                   for l in jax.tree.leaves(payload["cache"])))


def fingerprint(payload) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(payload["cache"]):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    h.update(str(payload["position"]).encode())
    return h.hexdigest()[:16]


def transfer(src_engine, dst_engine, session_id: str, *,
             dst_shardings=None, link_bw: float = 5e9,
             verify: bool = True, fail_injector=None) -> dict:
    """Move one session between engines. Returns transfer metadata.

    ``fail_injector``: test hook — callable that may raise mid-transfer to
    exercise the abort path (source must stay intact).
    """
    t0 = time.perf_counter()
    payload = src_engine.export_slot(session_id)
    nbytes = payload_bytes(payload)
    src_fp = fingerprint(payload) if verify else None

    if fail_injector is not None:
        fail_injector(payload)

    if dst_shardings is not None:
        payload = dict(payload)
        payload["cache"] = jax.device_put(payload["cache"], dst_shardings)

    dst_engine.import_slot(session_id, payload)
    if verify:
        dst_payload = dst_engine.export_slot(session_id)
        dst_fp = fingerprint(dst_payload)
        if dst_fp != src_fp:
            dst_engine.release_slot(session_id)
            raise IOError(f"state transfer corruption: {src_fp} != {dst_fp}")
    wall_s = time.perf_counter() - t0
    return {"bytes": nbytes, "wall_s": wall_s,
            "wire_s_at_link": nbytes / link_bw, "fingerprint": src_fp}
