"""Session-state transfer: the data plane of make-before-break migration.

``transfer(src_backend, dst_backend, session_id)`` exports the slot state on
the source anchor, re-shards it for the destination (between meshes this is
a ``jax.device_put`` with the destination shardings; on one host it is a
copy), verifies integrity, and installs it into a destination slot while
the source keeps serving. Only after the destination confirms does the
caller release the source slot (MigrationController drives the ordering).

Both sides speak the engine slot protocol (``export_slot`` / ``import_slot``
/ ``release_slot``): a raw :class:`~repro.serving.engine.InferenceEngine`,
a plane backend wrapping one (``RealEngineBackend``), or the stateful
``SimulatedEngine`` of the §V simulation arm — the same transfer code moves
all of them, which is what lets the VirtualClock scenarios exercise the
identical abort paths the real engines hit.

Family-specific payloads (DESIGN.md §4):
    dense/moe : full or windowed KV pages       (largest payload)
    hybrid    : RG-LRU states + window rings
    ssm       : conv + SSD states               (O(1) in context — cheapest)

Failure injection (``TransferInjections``) exposes every stage of the data
plane to tests: export failure, wire corruption (fingerprint mismatch),
import failure, target admission denial, and extra wire time that blows
τ_mig mid-transfer. Import-side failures roll the provisional destination
slot back before propagating, so an abort can never leak target state.
"""

from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import numpy as np


class AdmissionDenied(RuntimeError):
    """Target refused the migrated-in session (no free slot / injected
    refusal) — the caller maps this to COMPUTE_SCARCITY, distinct from
    STATE_TRANSFER_FAILURE in the Eq. (12) cause partition."""


@dataclass
class TransferInjections:
    """Plane-level failure-injection points for the migration data plane.

    Attach to ``ServingPlane.migration_inject``: export-side hooks fire on
    the SOURCE plane's injector, import-side hooks on the TARGET plane's.
    """
    #: called with the exported payload; raise to fail the export stage
    on_export: Optional[Callable[[dict], None]] = None
    #: called after the destination installed the payload; raise to fail the
    #: import stage (the provisional destination slot is rolled back)
    on_import: Optional[Callable[[dict], None]] = None
    #: payload -> payload applied "on the wire" (fingerprint corruption)
    corrupt: Optional[Callable[[dict], dict]] = None
    #: target refuses the session outright (admission denial)
    deny_admission: bool = False
    #: extra modeled wire seconds (τ_mig expiry mid-transfer)
    extra_wire_s: float = 0.0


def payload_bytes(payload) -> int:
    return int(sum(np.asarray(l).nbytes
                   for l in jax.tree.leaves(payload["cache"])))


def fingerprint(payload) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(payload["cache"]):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    h.update(str(payload["position"]).encode())
    # adapter identity is part of the session contract: the same cache
    # under a different tenant adapter is a DIFFERENT session state.
    # Absent/empty contributes no bytes, so base-model fingerprints are
    # unchanged from pre-adapter payloads.
    h.update(str(payload.get("adapter_id", "")).encode())
    return h.hexdigest()[:16]


def transfer(src_engine, dst_engine, session_id: str, *,
             dst_shardings=None, link_bw: float = 5e9,
             verify: bool = True, fail_injector=None,
             inject: Optional[TransferInjections] = None,
             scrub: Optional[Callable[[dict], dict]] = None,
             clock=None) -> dict:
    """Move one session between engines/backends. Returns transfer metadata.

    ``fail_injector``: legacy test hook — callable that may raise after the
    export to exercise the abort path (source must stay intact).
    ``inject``: staged :class:`TransferInjections`.
    ``scrub``: payload -> payload applied at the export boundary, BEFORE
    fingerprinting — the exposure-boundary hook for transfers that leave
    the administrative domain (roaming migration redacts everything but the
    slot-essential state, so the fingerprint covers exactly what crossed).
    ``clock``: when given, wall time is measured on it (VirtualClock arms
    measure zero wall — the modeled ``wire_s_at_link`` is what counts there).
    """
    _now = clock.now if clock is not None else time.perf_counter
    t0 = _now()
    payload = src_engine.export_slot(session_id)
    if scrub is not None:
        payload = scrub(payload)
    if inject is not None and inject.on_export is not None:
        inject.on_export(payload)
    nbytes = payload_bytes(payload)
    src_fp = fingerprint(payload) if verify else None

    if fail_injector is not None:
        fail_injector(payload)

    wire_payload = payload
    if dst_shardings is not None:
        wire_payload = dict(payload)
        wire_payload["cache"] = jax.device_put(payload["cache"],
                                               dst_shardings)
    if inject is not None and inject.corrupt is not None:
        wire_payload = inject.corrupt(dict(wire_payload))
    if inject is not None and inject.deny_admission:
        raise AdmissionDenied(
            f"target admission denied: {session_id} refused by injector")

    dst_engine.import_slot(session_id, wire_payload)
    try:
        if inject is not None and inject.on_import is not None:
            inject.on_import(wire_payload)
        if verify:
            dst_payload = dst_engine.export_slot(session_id)
            dst_fp = fingerprint(dst_payload)
            if dst_fp != src_fp:
                raise IOError(
                    f"state transfer corruption: {src_fp} != {dst_fp}")
    except BaseException:
        # provisional destination slot must never survive a failed import
        dst_engine.release_slot(session_id)
        raise
    wall_s = _now() - t0
    extra = inject.extra_wire_s if inject is not None else 0.0
    return {"bytes": nbytes, "wall_s": wall_s,
            "wire_s_at_link": nbytes / link_bw + extra,
            "fingerprint": src_fp}
